"""Decoder-only transformer LM family (llama/gemma/nemotron/qwen-MoE/...).

Covers the dense + MoE + sliding-window assigned architectures through one
config:

  * GQA attention with RoPE (any n_kv_heads, incl. MQA n_kv=1)
  * sliding-window / global layer interleave (gemma3's 5:1 pattern)
  * MoE layers (top-k routing, optional shared expert, optional dense/MoE
    interleave as in llama4-maverick)
  * squared-ReLU or (Swi)GLU FFN (nemotron-4 vs llama family)

Depth is executed as `lax.scan` over *pattern groups*: the layer pattern
(length P, e.g. gemma3's [local x5, global] or llama4's [dense, moe]) is
unrolled in the scan body with static window/moe flags per position, and the
scan runs over n_layers // P groups (plus an unrolled remainder).  The
lowered HLO is therefore O(P), not O(L) — required to keep 126-layer
llama3-405b compiles tractable on the CPU dry-run host, and it is also the
layout that makes FSDP weight-gather overlap work on real hardware.

Decode uses pre-allocated KV caches (B, S_max, Hkv, Dh) per layer, updated
in place via dynamic_update_slice (functional), with absolute-position RoPE
and causal masking driven by `cache_len` so the unwritten tail never leaks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .common import Params

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff: int                    # per-expert hidden
    n_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    impl: str = "dense"          # dense | capacity | sorted (see common.py)


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "silu"
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    # pattern description (length P): per-position sliding window (None =
    # global) and whether the FFN is MoE.
    layer_windows: Tuple[Optional[int], ...] = (None,)
    layer_moe: Tuple[bool, ...] = (False,)
    moe: Optional[MoECfg] = None
    tie_embeddings: bool = True
    dtype: Any = jnp.float32
    remat: bool = True
    # input mode: "tokens" (ids -> embed) or "embeddings" (stub frontends)
    input_mode: str = "tokens"

    @property
    def pattern(self) -> int:
        return len(self.layer_windows)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.pattern

    @property
    def n_rem(self) -> int:
        return self.n_layers - self.n_groups * self.pattern

    def param_count(self) -> int:
        c = self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        per_attn = self.d_model * self.d_head * (self.n_heads * 2 +
                                                 self.n_kv_heads * 2)
        for li in range(self.n_layers):
            c += per_attn + 2 * self.d_model
            if self.layer_moe[li % self.pattern] and self.moe:
                m = self.moe
                c += m.n_experts * (3 if self.gated_mlp else 2) * self.d_model * m.d_ff
                c += self.d_model * m.n_experts
                if m.n_shared:
                    c += (3 if self.gated_mlp else 2) * self.d_model * (
                        m.d_ff_shared or m.d_ff * m.n_shared)
            else:
                c += (3 if self.gated_mlp else 2) * self.d_model * self.d_ff
        return c


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _layer_params(key, cfg: TransformerCfg, pos: int) -> Params:
    ka, km, kn1, kn2 = jax.random.split(key, 4)
    p = {
        "ln_attn": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": common.attn_params(ka, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.d_head, cfg.dtype),
    }
    if cfg.layer_moe[pos] and cfg.moe is not None:
        m = cfg.moe
        p["moe"] = common.moe_params(km, cfg.d_model, m.d_ff, m.n_experts,
                                     cfg.dtype, m.n_shared, m.d_ff_shared or None)
    else:
        p["mlp"] = common.mlp_params(km, cfg.d_model, cfg.d_ff, cfg.dtype,
                                     gated=cfg.gated_mlp)
    return p


def init_params(key, cfg: TransformerCfg) -> Params:
    ke, kl, kr, kf = jax.random.split(key, 4)
    params: Params = {
        "embed": common.embed_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(kf, cfg.d_model, cfg.vocab, cfg.dtype)
    P = cfg.pattern
    # scan-stacked groups: per pattern position a stack of (n_groups, ...)
    stacks: List[Params] = []
    keys = jax.random.split(kl, max(cfg.n_groups, 1) * P).reshape(
        max(cfg.n_groups, 1), P, 2)
    for pos in range(P):
        if cfg.n_groups > 0:
            stacks.append(jax.vmap(lambda k: _layer_params(k, cfg, pos))(keys[:, pos]))
        else:
            stacks.append({})
    params["layer_stacks"] = stacks
    # unrolled remainder layers
    rem_keys = jax.random.split(kr, max(cfg.n_rem, 1))
    params["rem_layers"] = [
        _layer_params(rem_keys[i], cfg, i % P) for i in range(cfg.n_rem)]
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _block(p: Params, cfg: TransformerCfg, pos: int, x: Array,
           positions: Array, kv_cache=None, cache_len=None,
           attn_impl: str = "auto"):
    from ..distributed.sharding import constrain_acts
    window = cfg.layer_windows[pos]
    h = constrain_acts(common.rms_norm(x, p["ln_attn"]))
    attn_out, new_cache = common.attn_apply(
        p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        d_head=cfg.d_head, causal=True, window=window,
        rope_theta=cfg.rope_theta, positions=positions,
        kv_cache=kv_cache, cache_len=cache_len)
    x = constrain_acts(x + attn_out)
    h = constrain_acts(common.rms_norm(x, p["ln_mlp"]))
    if "moe" in p:
        m = cfg.moe
        moe_fn = {"dense": common.moe_apply,
                  "capacity": common.moe_capacity_apply,
                  "sorted": common.moe_sorted_apply}[m.impl]
        ff = moe_fn(p["moe"], h, top_k=m.top_k, act=cfg.act,
                    capacity_factor=m.capacity_factor)
    else:
        ff = common.mlp_apply(p["mlp"], h, act=cfg.act)
    return constrain_acts(x + ff), new_cache


def forward(params: Params, cfg: TransformerCfg, tokens: Array,
            *, embeddings: Optional[Array] = None,
            caches: Optional[List[Array]] = None,
            cache_len: Optional[Array] = None) -> Tuple[Array, Optional[List]]:
    """tokens: (B, S) int32 (or `embeddings` (B, S, D) for stub frontends).

    Returns (logits, new_caches).  If `caches` is given, runs in cached mode
    (prefill when cache_len is None and S>1 semantics handled by caller via
    cache_len=0; decode when S==1 and cache_len>0)."""
    from ..distributed.sharding import constrain_batch
    if embeddings is not None:
        x = embeddings.astype(cfg.dtype)
    else:
        x = params["embed"][tokens]
    x = constrain_batch(x)
    B, S = x.shape[:2]
    positions = common.decode_positions(S, cache_len)
    c_len = cache_len

    P = cfg.pattern

    def group_body(x, xs):
        stacks_g, caches_g = xs
        new_caches_g = []
        for pos in range(P):
            cache_pos = None if caches_g is None else tuple(caches_g[pos])
            x, nc = _block(stacks_g[pos], cfg, pos, x, positions,
                           kv_cache=cache_pos, cache_len=c_len)
            new_caches_g.append(nc)
        return x, new_caches_g

    if cfg.n_groups > 0:
        stacks = params["layer_stacks"]
        caches_scan = None
        if caches is not None:
            caches_scan = [caches[pos] for pos in range(P)]

        def scan_fn(x, xs):
            stacks_g = xs[0]
            caches_g = xs[1] if caches is not None else None
            body = group_body
            if cfg.remat and caches is None:
                body = jax.checkpoint(group_body,
                                      policy=jax.checkpoint_policies.nothing_saveable)
            x, new_c = body(x, (stacks_g, caches_g))
            if caches is not None:
                return x, tuple(tuple(c) for c in new_c)
            return x, None

        xs = (stacks, caches_scan) if caches is not None else (stacks,)
        x, scanned_caches = jax.lax.scan(scan_fn, x, xs)
    else:
        scanned_caches = None

    # remainder layers (unrolled)
    new_rem_caches = []
    for i, p in enumerate(params["rem_layers"]):
        pos = i % P
        cache_i = None
        if caches is not None:
            cache_i = caches[P + i] if isinstance(caches, list) else None
        x, nc = _block(p, cfg, pos, x, positions, kv_cache=cache_i,
                       cache_len=c_len)
        new_rem_caches.append(nc)

    x = common.rms_norm(x, params["ln_f"])
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["unembed"]

    new_caches = None
    if caches is not None:
        new_caches = [scanned_caches[pos] for pos in range(P)] + new_rem_caches
    return logits, new_caches


def init_cache(cfg: TransformerCfg, batch: int, max_len: int,
               dtype=None) -> List:
    """Per-pattern-position stacked caches: (n_groups, B, S, Hkv, Dh) k & v,
    plus unrolled remainder caches."""
    dtype = dtype or cfg.dtype
    shape_g = (cfg.n_groups, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    caches: List = [
        (jnp.zeros(shape_g, dtype), jnp.zeros(shape_g, dtype))
        for _ in range(cfg.pattern)
    ]
    shape_r = (batch, max_len, cfg.n_kv_heads, cfg.d_head)
    for _ in range(cfg.n_rem):
        caches.append((jnp.zeros(shape_r, dtype), jnp.zeros(shape_r, dtype)))
    return caches


# ---------------------------------------------------------------------------
# task-level entry points (train / prefill / decode)
# ---------------------------------------------------------------------------
def lm_loss(params: Params, cfg: TransformerCfg, tokens: Array,
            labels: Array, embeddings: Optional[Array] = None) -> Array:
    logits, _ = forward(params, cfg, tokens, embeddings=embeddings)
    return common.causal_lm_loss(logits, labels)


def prefill(params: Params, cfg: TransformerCfg, tokens: Array,
            max_len: int, embeddings: Optional[Array] = None):
    B = tokens.shape[0]
    caches = init_cache(cfg, B, max_len)
    logits, caches = forward(params, cfg, tokens, embeddings=embeddings,
                             caches=caches, cache_len=jnp.int32(0))
    return logits[:, -1], caches


def decode_step(params: Params, cfg: TransformerCfg, token: Array,
                caches: List, cache_len: Array):
    """token: (B, 1) int32; cache_len: () int32 — number of valid entries."""
    logits, caches = forward(params, cfg, token, caches=caches,
                             cache_len=cache_len)
    return logits[:, -1], caches

"""Full-model definitions for the non-decoder-only-transformer families.

  * RWKV6LM      — rwkv6-7b (attention-free, O(1)/token decode state)
  * Zamba2       — zamba2-2.7b (Mamba2 backbone + *shared* attention block
                   applied every `share_every` layers, zamba-style)
  * WhisperEncDec— whisper-base backbone (bidirectional encoder + causal
                   decoder with cross-attention; conv frontend is a stub —
                   `input_specs()` supplies precomputed frame embeddings)

All follow the transformer.py conventions: params are nested dicts, layer
stacks carry a leading L axis consumed by `lax.scan` (O(1)-in-depth HLO),
decode uses functional caches.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common, rwkv6, ssm
from .common import Params
from .rwkv6 import RWKV6Cfg
from .ssm import Mamba2Cfg

Array = jax.Array


# ===========================================================================
# RWKV6 LM
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class RWKV6LMCfg:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    chunk: int = 16
    dtype: Any = jnp.float32
    remat: bool = True

    @property
    def block(self) -> RWKV6Cfg:
        return RWKV6Cfg(d_model=self.d_model, n_heads=self.n_heads,
                        d_ff=self.d_ff, chunk=self.chunk, dtype=self.dtype)

    def param_count(self) -> int:
        d = self.d_model
        per_layer = 5 * d * d + d * (64 + 32) + (64 + 32) * 5 * d + 2 * d * self.d_ff + d * d
        return self.vocab * d + self.n_layers * per_layer


def rwkv_init(key, cfg: RWKV6LMCfg) -> Params:
    ke, kl = jax.random.split(key)
    keys = jax.random.split(kl, cfg.n_layers)
    stack = jax.vmap(lambda k: rwkv6.layer_params(k, cfg.block))(keys)
    return {
        "embed": common.embed_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
        "layers": stack,
    }


def rwkv_forward(params: Params, cfg: RWKV6LMCfg, tokens: Array,
                 embeddings: Optional[Array] = None,
                 caches=None) -> Tuple[Array, Optional[Any]]:
    from ..distributed.sharding import constrain_batch
    x = params["embed"][tokens] if embeddings is None else embeddings.astype(cfg.dtype)
    x = constrain_batch(x)

    def body(x, xs):
        layer_p, cache = xs if caches is not None else (xs[0], None)
        x, new_cache = rwkv6.layer_apply(layer_p, cfg.block, x, cache=cache)
        return x, new_cache

    if caches is not None:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_caches = jax.lax.scan(lambda c, xs: fn(c, (xs,)), x, params["layers"])
    x = common.rms_norm(x, params["ln_f"])
    return x @ params["embed"].T, new_caches


def rwkv_init_cache(cfg: RWKV6LMCfg, batch: int):
    one = rwkv6.init_layer_cache(cfg.block, batch, cfg.dtype)
    return jax.tree.map(lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), one)


# ===========================================================================
# Zamba2-style hybrid: Mamba2 backbone + shared attention block
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class Zamba2Cfg:
    name: str
    n_layers: int                 # number of mamba2 layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int                     # shared-block FFN width
    vocab: int
    ssm_state: int = 64
    share_every: int = 6          # shared attn block applied after every k mamba layers
    chunk: int = 128
    dtype: Any = jnp.float32
    remat: bool = True

    @property
    def mamba(self) -> Mamba2Cfg:
        return Mamba2Cfg(d_model=self.d_model, d_state=self.ssm_state,
                         chunk=self.chunk, dtype=self.dtype)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.share_every

    def param_count(self) -> int:
        m = self.mamba
        d_in_proj = 2 * m.d_inner + 2 * m.d_state + m.n_heads
        per_mamba = self.d_model * d_in_proj + m.d_conv * m.conv_dim + m.d_inner * self.d_model
        shared = self.d_model * self.d_head * (self.n_heads * 2 + self.n_kv_heads * 2) \
            + 3 * self.d_model * self.d_ff
        return self.vocab * self.d_model + self.n_layers * per_mamba + shared


def zamba_init(key, cfg: Zamba2Cfg) -> Params:
    ke, km, ks, kmm = jax.random.split(key, 4)
    keys = jax.random.split(km, cfg.n_layers)

    def one_layer(k):
        return {"ln": jnp.zeros((cfg.d_model,), cfg.dtype),
                "mamba": ssm.mamba2_params(k, cfg.mamba)}

    stack = jax.vmap(one_layer)(keys)
    shared = {
        "ln_attn": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), cfg.dtype),
        "attn": common.attn_params(ks, cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.d_head, cfg.dtype),
        "mlp": common.mlp_params(kmm, cfg.d_model, cfg.d_ff, cfg.dtype),
    }
    return {
        "embed": common.embed_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "ln_f": jnp.zeros((cfg.d_model,), cfg.dtype),
        "layers": stack,
        "shared": shared,
    }


def _zamba_shared_block(shared: Params, cfg: Zamba2Cfg, x: Array, positions,
                        kv_cache=None, cache_len=None):
    h = common.rms_norm(x, shared["ln_attn"])
    attn_out, new_kv = common.attn_apply(
        shared["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        d_head=cfg.d_head, causal=True, rope_theta=10000.0,
        positions=positions, kv_cache=kv_cache, cache_len=cache_len)
    from ..distributed.sharding import constrain_acts
    x = constrain_acts(x + attn_out)
    h = common.rms_norm(x, shared["ln_mlp"])
    return constrain_acts(x + common.mlp_apply(shared["mlp"], h)), new_kv


def zamba_forward(params: Params, cfg: Zamba2Cfg, tokens: Array,
                  embeddings: Optional[Array] = None,
                  caches=None, cache_len=None):
    """caches = (mamba_caches stacked (L, ...), kv_caches stacked (n_groups, ...))."""
    from ..distributed.sharding import constrain_batch
    x = params["embed"][tokens] if embeddings is None else embeddings.astype(cfg.dtype)
    x = constrain_batch(x)
    B, S = x.shape[:2]
    positions = common.decode_positions(S, cache_len)
    k = cfg.share_every
    G = cfg.n_groups
    # reshape layer stack into (G, k, ...) groups
    grouped = jax.tree.map(lambda t: t.reshape((G, k) + t.shape[1:]), params["layers"])
    m_caches, kv_caches = (None, None) if caches is None else caches
    if m_caches is not None:
        m_caches = jax.tree.map(lambda t: t.reshape((G, k) + t.shape[1:]), m_caches)

    def group_body(x, xs):
        layers_g, mcache_g, kv_g = xs

        def inner(x, ys):
            lp, mc = ys
            h, new_mc = ssm.mamba2_apply(lp["mamba"], cfg.mamba,
                                         common.rms_norm(x, lp["ln"]), cache=mc)
            from ..distributed.sharding import constrain_acts
            return constrain_acts(x + h), new_mc

        if mcache_g is None:
            x, _ = jax.lax.scan(lambda c, ys: inner(c, (ys, None)), x, layers_g)
            new_mc = None
        else:
            x, new_mc = jax.lax.scan(inner, x, (layers_g, mcache_g))
        kv = None if kv_g is None else tuple(kv_g)
        x, new_kv = _zamba_shared_block(params["shared"], cfg, x, positions,
                                        kv_cache=kv, cache_len=cache_len)
        return x, (new_mc, new_kv)

    body = group_body
    if cfg.remat and caches is None:
        body = jax.checkpoint(group_body, policy=jax.checkpoint_policies.nothing_saveable)

    if caches is None:
        x, _ = jax.lax.scan(lambda c, xs: body(c, (xs, None, None)), x, grouped)
        new_caches = None
    else:
        def scan_fn(c, xs):
            x, out = body(c, xs)
            return x, out
        x, (new_m, new_kv) = jax.lax.scan(scan_fn, x, (grouped, m_caches, kv_caches))
        new_m = jax.tree.map(lambda t: t.reshape((G * k,) + t.shape[2:]), new_m)
        new_caches = (new_m, new_kv)

    x = common.rms_norm(x, params["ln_f"])
    return x @ params["embed"].T, new_caches


def zamba_init_cache(cfg: Zamba2Cfg, batch: int, max_len: int):
    one_m = ssm.init_mamba_cache(cfg.mamba, batch, cfg.dtype)
    m = jax.tree.map(lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), one_m)
    kv_shape = (cfg.n_groups, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    kv = (jnp.zeros(kv_shape, cfg.dtype), jnp.zeros(kv_shape, cfg.dtype))
    return (m, kv)


# ===========================================================================
# Whisper-style encoder-decoder backbone
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    n_audio_ctx: int = 1500       # encoder frames after the (stubbed) conv frontend
    dtype: Any = jnp.float32
    remat: bool = True

    def param_count(self) -> int:
        d, dh = self.d_model, self.d_head
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        mlp = 2 * d * self.d_ff
        enc = self.n_enc_layers * (attn + mlp)
        dec = self.n_dec_layers * (2 * attn + mlp)
        return self.vocab * d + enc + dec + self.n_audio_ctx * d


def encdec_init(key, cfg: EncDecCfg) -> Params:
    ke, kenc, kdec, kp = jax.random.split(key, 4)

    def enc_layer(k):
        ka, km = jax.random.split(k)
        return {
            "ln_attn": jnp.zeros((cfg.d_model,), cfg.dtype),
            "ln_mlp": jnp.zeros((cfg.d_model,), cfg.dtype),
            "attn": common.attn_params(ka, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.d_head, cfg.dtype),
            "mlp": common.mlp_params(km, cfg.d_model, cfg.d_ff, cfg.dtype,
                                     gated=False),
        }

    def dec_layer(k):
        ka, kx, km = jax.random.split(k, 3)
        p = enc_layer(jax.random.fold_in(k, 0))
        p["ln_xattn"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        p["xattn"] = common.attn_params(kx, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.d_head, cfg.dtype)
        return p

    return {
        "embed": common.embed_init(ke, cfg.vocab, cfg.d_model, cfg.dtype),
        "pos_audio": (jax.random.normal(kp, (cfg.n_audio_ctx, cfg.d_model),
                                        jnp.float32) * 0.01).astype(cfg.dtype),
        "ln_enc": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_dec": jnp.zeros((cfg.d_model,), cfg.dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(kenc, cfg.n_enc_layers)),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(kdec, cfg.n_dec_layers)),
    }


def encode(params: Params, cfg: EncDecCfg, frames: Array) -> Array:
    """frames: (B, S_audio, D) stub frontend embeddings -> encoder memory."""
    S = frames.shape[1]
    x = frames.astype(cfg.dtype) + params["pos_audio"][:S][None]

    def body(x, lp):
        h = common.rms_norm(x, lp["ln_attn"])
        a, _ = common.attn_apply(lp["attn"], h, n_heads=cfg.n_heads,
                                 n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                                 causal=False, rope_theta=0.0,
                                 positions=jnp.arange(S))
        from ..distributed.sharding import constrain_acts
        x = constrain_acts(x + a)
        h = common.rms_norm(x, lp["ln_mlp"])
        return constrain_acts(x + common.mlp_apply(lp["mlp"], h, act="gelu")), None

    fn = body
    if cfg.remat:
        fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return common.rms_norm(x, params["ln_enc"])


def decode_forward(params: Params, cfg: EncDecCfg, tokens: Array, memory: Array,
                   caches=None, cache_len=None):
    """Decoder over `tokens` with cross-attention into `memory`.
    caches: stacked self-attn KV (L, B, S_max, Hkv, Dh) pairs."""
    from ..distributed.sharding import constrain_batch
    x = constrain_batch(params["embed"][tokens])
    S = x.shape[1]
    positions = common.decode_positions(S, cache_len)

    def body(x, xs):
        lp, kv = xs if caches is not None else (xs[0], None)
        h = common.rms_norm(x, lp["ln_attn"])
        a, new_kv = common.attn_apply(lp["attn"], h, n_heads=cfg.n_heads,
                                      n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                                      causal=True, rope_theta=10000.0,
                                      positions=positions,
                                      kv_cache=kv, cache_len=cache_len)
        x = x + a
        h = common.rms_norm(x, lp["ln_xattn"])
        a, _ = common.attn_apply(lp["xattn"], h, n_heads=cfg.n_heads,
                                 n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                                 causal=False, rope_theta=0.0,
                                 positions=positions, x_kv=memory)
        from ..distributed.sharding import constrain_acts
        x = constrain_acts(x + a)
        h = common.rms_norm(x, lp["ln_mlp"])
        return constrain_acts(x + common.mlp_apply(lp["mlp"], h, act="gelu")), new_kv

    if caches is not None:
        x, new_caches = jax.lax.scan(lambda c, xs: body(c, xs), x,
                                     (params["dec_layers"], caches))
    else:
        fn = body
        if cfg.remat:
            fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_caches = jax.lax.scan(lambda c, xs: fn(c, (xs,)), x,
                                     params["dec_layers"])
    x = common.rms_norm(x, params["ln_dec"])
    return x @ params["embed"].T, new_caches


def encdec_init_cache(cfg: EncDecCfg, batch: int, max_len: int):
    shape = (cfg.n_dec_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return (jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype))

"""RWKV6 "Finch" token mixing (Peng et al. 2024, arXiv:2404.05892).

Attention-free linear recurrence with *data-dependent* per-channel decay:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (per head, S: (d_k, d_v))
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

with w_t = exp(-exp(d_t)) produced by a token-shifted LoRA, and the "bonus"
u giving the current token a decay-free path.  O(1)/token decode state makes
rwkv6-7b a `long_500k` architecture in the assignment.

Chunked execution (the training path) mirrors the SSD trick in ssm.py but
with per-*channel* decay: within chunks of length Q the pairwise decay
tensor D[t, s, d] = B_t[d] - A_s[d] (A = inclusive, B = exclusive cumsum of
log w) is materialized and masked *before* exponentiation, so every exponent
is <= 0 — numerically exact with no decay clamping; chunk boundary states
propagate through a `lax.scan`.  `rwkv6_sequential` is the per-token oracle
(tests + decode).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import common
from .common import Params

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RWKV6Cfg:
    d_model: int
    n_heads: int                 # head size = d_model // n_heads
    d_ff: int
    lora_decay: int = 64         # decay LoRA rank
    lora_mix: int = 32           # token-shift mix LoRA rank
    chunk: int = 16              # intra-chunk tile (exponent-safe, see module doc)
    dtype: Any = jnp.float32

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# core recurrence
# ---------------------------------------------------------------------------
def rwkv6_chunked(r: Array, k: Array, v: Array, w_log: Array, u: Array,
                  chunk: int, state0: Optional[Array] = None
                  ) -> Tuple[Array, Array]:
    """r/k: (B, S, H, Dk); v: (B, S, H, Dv); w_log = log w_t (<= 0) same shape
    as k; u: (H, Dk).  Returns (y (B,S,H,Dv), final_state (B,H,Dk,Dv))."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32

    rc = r.reshape(b, nc, chunk, h, dk).astype(f32)
    kc = k.reshape(b, nc, chunk, h, dk).astype(f32)
    vc = v.reshape(b, nc, chunk, h, dv).astype(f32)
    wc = w_log.reshape(b, nc, chunk, h, dk).astype(f32)

    A = jnp.cumsum(wc, axis=2)                     # inclusive: A_t = sum_{j<=t} log w_j
    Bx = A - wc                                    # exclusive: B_t = A_{t-1}
    A_last = A[:, :, -1]                           # (b, nc, h, dk)

    # ---- intra-chunk: y_t = sum_{s<t} (r_t . exp(B_t - A_s) . k_s) v_s
    #      + (r_t . u . k_t) v_t   — pairwise decay masked BEFORE exp.
    D = Bx[:, :, :, None] - A[:, :, None, :]       # (b, nc, t, s, h, dk)
    t_idx = jnp.arange(chunk)
    strict = (t_idx[:, None] > t_idx[None, :])     # s < t
    D = jnp.where(strict[None, None, :, :, None, None], D, -jnp.inf)
    scores = jnp.einsum("bcthd,bctshd,bcshd->bcths", rc, jnp.exp(D), kc)
    diag = jnp.einsum("bcthd,hd,bcthd->bcth", rc, u.astype(f32), kc)
    scores = scores + diag[..., None] * jnp.eye(chunk, dtype=f32)[:, None, :]
    y = jnp.einsum("bcths,bcshd->bcthd", scores, vc)

    # ---- chunk summary: S_out = diag(exp(A_Q)) S_in + sum_s exp(A_Q - A_s) k_s v_s
    decay_out = jnp.exp(A_last[:, :, None] - A)    # (b, nc, t, h, dk), exponent <= 0
    chunk_states = jnp.einsum("bcshd,bcshd,bcshe->bchde", decay_out, kc, vc)

    if state0 is None:
        state0 = jnp.zeros((b, h, dk, dv), f32)

    def scan_fn(S, xs):
        st, dlast = xs                             # (b,h,dk,dv), (b,h,dk)
        S_new = jnp.exp(dlast)[..., None] * S + st
        return S_new, S                            # emit state *entering* chunk

    final, S_prev = jax.lax.scan(
        scan_fn, state0.astype(f32),
        (chunk_states.transpose(1, 0, 2, 3, 4), A_last.transpose(1, 0, 2, 3)))
    S_prev = S_prev.transpose(1, 0, 2, 3, 4)       # (b, nc, h, dk, dv)

    # ---- entering state's contribution: y_t += (r_t . exp(B_t)) @ S_in
    y = y + jnp.einsum("bcthd,bcthd,bchde->bcthe", rc, jnp.exp(Bx), S_prev)
    return y.reshape(b, s, h, dv).astype(r.dtype), final


def rwkv6_sequential(r, k, v, w_log, u, state0=None):
    """Per-token oracle for rwkv6_chunked (tests + decode)."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    S0 = jnp.zeros((b, h, dk, dv), f32) if state0 is None else state0.astype(f32)

    def step(S, t):
        rt = r[:, t].astype(f32)
        kt = k[:, t].astype(f32)
        vt = v[:, t].astype(f32)
        wt = jnp.exp(w_log[:, t].astype(f32))
        y = jnp.einsum("bhd,bhde->bhe", rt, S) + \
            jnp.einsum("bhd,hd,bhd,bhe->bhe", rt, u.astype(f32), kt, vt)
        S = wt[..., None] * S + jnp.einsum("bhd,bhe->bhde", kt, vt)
        return S, y

    S, ys = jax.lax.scan(step, S0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), S


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _lora(key, d: int, rank: int, d_out: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"a": common.dense_init(k1, d, rank, dtype),
            "b": (jax.random.normal(k2, (rank, d_out), jnp.float32) * 0.01).astype(dtype)}


def _lora_apply(p, x):
    return jnp.tanh(x @ p["a"]) @ p["b"]


def time_mix_params(key, cfg: RWKV6Cfg) -> Params:
    ks = jax.random.split(key, 10)
    d, h = cfg.d_model, cfg.n_heads
    return {
        "mu": (jax.random.uniform(ks[0], (5, d), jnp.float32)).astype(cfg.dtype),
        "mix_lora": _lora(ks[1], d, cfg.lora_mix, 5 * d, cfg.dtype),
        "wr": common.dense_init(ks[2], d, d, cfg.dtype),
        "wk": common.dense_init(ks[3], d, d, cfg.dtype),
        "wv": common.dense_init(ks[4], d, d, cfg.dtype),
        "wg": common.dense_init(ks[5], d, d, cfg.dtype),
        "wo": common.dense_init(ks[6], d, d, cfg.dtype),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "decay_lora": _lora(ks[7], d, cfg.lora_decay, d, cfg.dtype),
        "u": (jax.random.normal(ks[8], (h, cfg.d_head), jnp.float32) * 0.5),
        "ln_out": jnp.ones((d,), jnp.float32),
    }


def time_mix_apply(p: Params, cfg: RWKV6Cfg, x: Array,
                   cache: Optional[Tuple[Array, Array]] = None
                   ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """x: (B, S, D).  cache = (x_prev (B, 1, D), state (B, H, Dk, Dv))."""
    B, S, D = x.shape
    H, Dh = cfg.n_heads, cfg.d_head
    x_prev = cache[0] if cache is not None else jnp.zeros_like(x[:, :1])
    xs = jnp.concatenate([x_prev.astype(x.dtype), x[:, :-1]], axis=1)  # token shift
    dx = xs - x
    # data-dependent five-way mix (r, k, v, g, w) — Finch's dynamic lerp
    mix = p["mu"][:, None, None] + _lora_apply(p["mix_lora"], x).reshape(B, S, 5, D).transpose(2, 0, 1, 3)
    xr, xk, xv, xg, xw = [x + mix[i] * dx for i in range(5)]

    r = (xr @ p["wr"]).reshape(B, S, H, Dh)
    k = (xk @ p["wk"]).reshape(B, S, H, Dh)
    v = (xv @ p["wv"]).reshape(B, S, H, Dh)
    g = jax.nn.silu(xg @ p["wg"])
    d_t = p["decay_base"][None, None] + _lora_apply(p["decay_lora"], xw).astype(jnp.float32)
    w_log = -jnp.exp(d_t).reshape(B, S, H, Dh)     # log w_t = -exp(d_t) <= 0

    if cache is not None:
        y, state = rwkv6_sequential(r, k, v, w_log, p["u"], state0=cache[1])
        new_cache = (x[:, -1:], state)
    else:
        pad = (-S) % cfg.chunk
        if pad:
            r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (r, k, v))
            w_log = jnp.pad(w_log, ((0, 0), (0, pad), (0, 0), (0, 0)))
        y, _ = rwkv6_chunked(r, k, v, w_log, p["u"], cfg.chunk)
        y = y[:, :S]
        new_cache = None

    # per-head group norm then output gate
    y = y.reshape(B, S, H, Dh)
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = y32.var(-1, keepdims=True)
    y = ((y32 - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = (y * p["ln_out"]).astype(x.dtype) * g
    return y @ p["wo"], new_cache


def channel_mix_params(key, cfg: RWKV6Cfg) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "mu": jax.random.uniform(k1, (2, d), jnp.float32).astype(cfg.dtype),
        "wk": common.dense_init(k2, d, cfg.d_ff, cfg.dtype),
        "wv": common.dense_init(k3, cfg.d_ff, d, cfg.dtype),
        "wr": common.dense_init(k4, d, d, cfg.dtype),
    }


def channel_mix_apply(p: Params, x: Array,
                      x_prev: Optional[Array] = None
                      ) -> Tuple[Array, Optional[Array]]:
    B, S, D = x.shape
    xp = x_prev if x_prev is not None else jnp.zeros_like(x[:, :1])
    xs = jnp.concatenate([xp.astype(x.dtype), x[:, :-1]], axis=1)
    dx = xs - x
    xk = x + p["mu"][0] * dx
    xr = x + p["mu"][1] * dx
    kk = jax.nn.relu(xk @ p["wk"])
    out = (kk * kk) @ p["wv"]
    return jax.nn.sigmoid(xr @ p["wr"]) * out, (x[:, -1:] if x_prev is not None else None)


def layer_params(key, cfg: RWKV6Cfg) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln2": jnp.zeros((cfg.d_model,), cfg.dtype),
        "tmix": time_mix_params(k1, cfg),
        "cmix": channel_mix_params(k2, cfg),
    }


def layer_apply(p: Params, cfg: RWKV6Cfg, x: Array, cache=None):
    """cache = (x_prev_t, state, x_prev_c) for decode."""
    from ..distributed.sharding import constrain_acts
    tc = None if cache is None else (cache[0], cache[1])
    h, new_t = time_mix_apply(p["tmix"], cfg, common.rms_norm(x, p["ln1"]), cache=tc)
    x = constrain_acts(x + h)
    cp = None if cache is None else cache[2]
    h, new_c = channel_mix_apply(p["cmix"], common.rms_norm(x, p["ln2"]), x_prev=cp)
    x = constrain_acts(x + h)
    new_cache = None if cache is None else (new_t[0], new_t[1], new_c)
    return x, new_cache


def init_layer_cache(cfg: RWKV6Cfg, batch: int, dtype=None):
    dtype = dtype or cfg.dtype
    return (jnp.zeros((batch, 1, cfg.d_model), dtype),
            jnp.zeros((batch, cfg.n_heads, cfg.d_head, cfg.d_head), jnp.float32),
            jnp.zeros((batch, 1, cfg.d_model), dtype))

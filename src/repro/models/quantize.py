"""Low-precision score-net serving: bf16 and int8 weight-quantized params.

The diffusion round has exactly two costs — the score-net eval and the
state update.  The update is fused and bitwise (`kernels/round_fused`);
the eval's weights are the remaining HBM traffic, and this module halves
(bf16) or quarters (int8) their residency behind a per-request /
per-engine `precision` flag (`DiffusionEngine(..., precision=)` /
`SampleRequest.precision`).

The tolerance tier is differential, split by layer:

  * coefficient / state-update layer — BITWISE at every precision: the
    round commit consumes the net's eps output but never the params, so
    engine(precision=p) equals "p-precision eval + f32 stitched chain"
    bit-for-bit, and solo == mixed stays bitwise *within* a precision
    class (each (family, precision) class is its own compiled variant
    masked by `state.prec`, exactly like the family axis).
  * net layer — bounded error vs the f32 eval, with the documented
    `NET_TOLERANCES` below (locked by tests/test_lowprec.py under the
    pinned `ci` hypothesis profile).

Weight-only quantization: int8 stores a per-output-channel symmetric
`QTensor(q, scale)` for every float matrix leaf (ndim >= 2) and leaves
vectors (biases, norms, time embeddings) in f32; the dequant happens
inside the compiled round program (`wrap_eps_model`), so the resident
copy really is int8.  bf16 casts every float leaf; activations stay f32
(jnp promotes f32 @ bf16 -> f32).  `precision='f32'` is the identity on
both params and eps_model — the warmed f32 graphs are untouched, byte
for byte.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

PRECISIONS = ("f32", "bf16", "int8")

# documented bounded-error tolerances of the *net* layer vs the f32 eval
# (relative to the eps output's scale; see tests/test_lowprec.py).  bf16
# carries ~8 mantissa bits (~2^-8 relative per op); int8 weight rounding
# is ~scale/2 per weight, amplified by depth — both measured with slack
# on the repo's score nets.
NET_TOLERANCES = {
    "bf16": {"rtol": 3e-2, "atol": 3e-2},
    "int8": {"rtol": 2e-1, "atol": 2e-1},
}


def prec_index(precision: str) -> int:
    """The `state.prec` class id of a precision name (engine/state axis)."""
    return PRECISIONS.index(check_precision(precision))


def check_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r}; "
                         f"choose from {PRECISIONS}")
    return precision


class QTensor(NamedTuple):
    """Per-output-channel symmetric int8 weight: w ~ q * scale, q int8 in
    [-127, 127], scale f32 broadcast over all but the last axis.  A pytree
    (both leaves traverse under jit/device_put), so quantized params ride
    every existing placement path."""
    q: Array                    # int8, w.shape
    scale: Array                # f32, (w.shape[-1],)

    def dequant(self) -> Array:
        return self.q.astype(jnp.float32) * self.scale


def _quantize_leaf_int8(w: Array) -> QTensor:
    amax = jnp.max(jnp.abs(w), axis=tuple(range(w.ndim - 1)))
    scale = jnp.maximum(amax, 1e-12).astype(jnp.float32) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def _is_float(x: Any) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


def quantize_tree(params: Any, precision: str) -> Any:
    """Params pytree -> its `precision` resident form.  'f32' returns the
    input unchanged (same buffers); 'bf16' casts float leaves; 'int8'
    replaces float matrices (ndim >= 2) with `QTensor`s and leaves
    vectors/scalars in f32 (weight-only quantization)."""
    check_precision(precision)
    if precision == "f32":
        return params
    if precision == "bf16":
        return jax.tree.map(
            lambda x: x.astype(jnp.bfloat16) if _is_float(x) else x, params)
    return jax.tree.map(
        lambda x: _quantize_leaf_int8(x)
        if _is_float(x) and x.ndim >= 2 else x, params)


def dequantize_tree(params: Any) -> Any:
    """Inverse residency transform for the eval: QTensor leaves dequant to
    f32 *inside* the compiled program (the stored copy stays int8)."""
    return jax.tree.map(
        lambda x: x.dequant() if isinstance(x, QTensor) else x, params,
        is_leaf=lambda x: isinstance(x, QTensor))


def wrap_eps_model(eps_model, precision: str):
    """The round-step's eval hook for a precision class.  'f32' is the
    identity — the warmed full-precision graphs are untouched.  'bf16'
    and 'int8' dequantize/consume the resident low-precision params and
    pin the eps output back to f32, so the state-update layer downstream
    sees the exact dtype/shape contract of the f32 path."""
    check_precision(precision)
    if precision == "f32":
        return eps_model

    if precision == "bf16":
        def eval_bf16(params, u, t):
            return eps_model(params, u, t).astype(jnp.float32)
        return eval_bf16

    def eval_int8(params, u, t):
        return eps_model(dequantize_tree(params), u, t).astype(jnp.float32)
    return eval_int8

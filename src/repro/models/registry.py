"""Uniform architecture interface over every model family in the zoo.

An `Arch` wraps a family config (transformer / rwkv / zamba / encdec) behind
one API the launcher, dry-run, trainer and server all consume:

    init(key)                  -> params           (never called at full size
                                                    on the dry-run host)
    param_shapes()             -> ShapeDtypeStruct pytree  (eval_shape)
    loss(params, batch)        -> scalar           (causal-LM xent)
    prefill(params, batch)     -> (logits_last, caches)
    decode(params, batch)      -> (logits, caches) one-token serve step
    cache_shapes(B, S)         -> ShapeDtypeStruct pytree
    input_specs(shape)         -> {name: ShapeDtypeStruct} for lowering

`input_mode` follows the assignment: "tokens" for LM archs, "embeddings" for
the audio/VLM entries whose modality frontend is a stub (`input_specs`
supplies precomputed frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import transformer, zoo

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str                   # transformer | rwkv | zamba | encdec
    cfg: Any
    input_mode: str = "tokens"    # tokens | embeddings (stub frontend)
    subquadratic: bool = False    # eligible for long_500k
    supports_decode: bool = True
    frontend_ctx: int = 0         # encoder frames (encdec) / patch tokens (vlm)
    gddim_applicable: bool = True # can act as eps-regressor for diffusion-LM
    notes: str = ""

    def shape_applicable(self, shape: str) -> Tuple[bool, str]:
        cell = SHAPES[shape]
        if cell.kind == "decode" and not self.supports_decode:
            return False, "encoder-only arch has no decode step"
        if shape == "long_500k" and not self.subquadratic:
            return False, "pure full-attention arch; 500k ctx needs sub-quadratic layers (DESIGN.md §5)"
        return True, ""


class Arch:
    def __init__(self, spec: ArchSpec):
        self.spec = spec
        self.cfg = spec.cfg

    # ---- params ----------------------------------------------------------------
    def init(self, key) -> Any:
        f = {
            "transformer": lambda: transformer.init_params(key, self.cfg),
            "rwkv": lambda: zoo.rwkv_init(key, self.cfg),
            "zamba": lambda: zoo.zamba_init(key, self.cfg),
            "encdec": lambda: zoo.encdec_init(key, self.cfg),
        }[self.spec.family]
        return f()

    def param_shapes(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))  # staticcheck: disable=SC102 (eval_shape: the key is abstract, no bits are ever drawn)

    def param_count(self) -> int:
        shapes = self.param_shapes()
        return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))

    # ---- training --------------------------------------------------------------
    def loss(self, params: Any, batch: Dict[str, Array]) -> Array:
        fam = self.spec.family
        labels = batch["labels"]
        if fam == "transformer":
            logits, _ = transformer.forward(
                params, self.cfg, batch.get("tokens"),
                embeddings=batch.get("embeddings"))
        elif fam == "rwkv":
            logits, _ = zoo.rwkv_forward(params, self.cfg, batch.get("tokens"),
                                         embeddings=batch.get("embeddings"))
        elif fam == "zamba":
            logits, _ = zoo.zamba_forward(params, self.cfg, batch.get("tokens"),
                                          embeddings=batch.get("embeddings"))
        elif fam == "encdec":
            memory = zoo.encode(params, self.cfg, batch["frames"])
            logits, _ = zoo.decode_forward(params, self.cfg, batch["tokens"], memory)
        else:
            raise ValueError(fam)
        from .common import causal_lm_loss
        return causal_lm_loss(logits, labels)

    # ---- serving ----------------------------------------------------------------
    def cache_shapes(self, batch: int, max_len: int) -> Any:
        fam = self.spec.family
        if fam == "transformer":
            return jax.eval_shape(lambda: transformer.init_cache(self.cfg, batch, max_len))
        if fam == "rwkv":
            return jax.eval_shape(lambda: zoo.rwkv_init_cache(self.cfg, batch))
        if fam == "zamba":
            return jax.eval_shape(lambda: zoo.zamba_init_cache(self.cfg, batch, max_len))
        if fam == "encdec":
            return jax.eval_shape(lambda: zoo.encdec_init_cache(self.cfg, batch, max_len))
        raise ValueError(fam)

    def init_cache(self, batch: int, max_len: int) -> Any:
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shapes(batch, max_len))

    def cache_batch_axes(self, max_len: int) -> Any:
        """Pytree (same structure as the cache) of ints: which axis of each
        cache leaf is the batch/slot axis.  Probed by diffing shapes at two
        batch sizes, so it is correct for any family layout (KV caches carry
        batch at axis 1 under the scan-stacked group axis; recurrent states
        at axis 1 under the layer axis; unrolled remainder KV at axis 0)."""
        a = self.cache_shapes(2, max_len)
        b = self.cache_shapes(3, max_len)

        def axis(sa, sb):
            diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                    if x != y]
            if len(diff) != 1:
                raise ValueError(f"ambiguous batch axis for leaf {sa.shape}")
            return diff[0]

        return jax.tree.map(axis, a, b)

    def encode_memory(self, params: Any, frames: Optional[Array]) -> Optional[Array]:
        """Encoder memory for encdec archs ((B, ctx, D)); None otherwise."""
        if self.spec.family != "encdec":
            return None
        return zoo.encode(params, self.cfg, frames)

    def decode(self, params: Any, token: Array, caches: Any, cache_len: Array,
               memory: Optional[Array] = None) -> Tuple[Array, Any]:
        """One-token serve step.  token: (B, 1) int32."""
        fam = self.spec.family
        if fam == "transformer":
            logits, caches = transformer.forward(params, self.cfg, token,
                                                 caches=caches, cache_len=cache_len)
        elif fam == "rwkv":
            logits, caches = zoo.rwkv_forward(params, self.cfg, token, caches=caches)
        elif fam == "zamba":
            logits, caches = zoo.zamba_forward(params, self.cfg, token,
                                               caches=caches, cache_len=cache_len)
        elif fam == "encdec":
            logits, caches = zoo.decode_forward(params, self.cfg, token, memory,
                                                caches=caches, cache_len=cache_len)
        else:
            raise ValueError(fam)
        return logits[:, -1], caches

    def prefill(self, params: Any, batch: Dict[str, Array], max_len: int
                ) -> Tuple[Array, Any]:
        fam = self.spec.family
        tokens = batch.get("tokens")
        B = (tokens if tokens is not None else batch["embeddings"]).shape[0]
        caches = self.init_cache(B, max_len)
        if fam == "transformer":
            logits, caches = transformer.forward(
                params, self.cfg, tokens, embeddings=batch.get("embeddings"),
                caches=caches, cache_len=jnp.int32(0))
        elif fam == "rwkv":
            logits, caches = zoo.rwkv_forward(params, self.cfg, tokens,
                                              embeddings=batch.get("embeddings"),
                                              caches=caches)
        elif fam == "zamba":
            logits, caches = zoo.zamba_forward(params, self.cfg, tokens,
                                               embeddings=batch.get("embeddings"),
                                               caches=caches, cache_len=jnp.int32(0))
        elif fam == "encdec":
            memory = batch.get("memory")
            if memory is None:
                memory = zoo.encode(params, self.cfg, batch["frames"])
            logits, caches = zoo.decode_forward(params, self.cfg, tokens, memory,
                                                caches=caches, cache_len=jnp.int32(0))
        else:
            raise ValueError(fam)
        return logits[:, -1], caches

    # ---- lowering inputs ----------------------------------------------------------
    def input_specs(self, shape: str) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every input of the step this shape
        lowers (weak-type-correct, shardable, no device allocation)."""
        cell = SHAPES[shape]
        B, S = cell.global_batch, cell.seq_len
        d = getattr(self.cfg, "d_model")
        specs: Dict[str, Any] = {}
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cell.kind == "train":
            if self.spec.input_mode == "embeddings":
                specs["embeddings"] = jax.ShapeDtypeStruct((B, S, d), jnp.float32)
            else:
                specs["tokens"] = tok
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            if self.spec.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, self.spec.frontend_ctx, d), jnp.float32)
                specs["tokens"] = tok
                specs.pop("embeddings", None)
        elif cell.kind == "prefill":
            if self.spec.input_mode == "embeddings" and self.spec.family != "encdec":
                specs["embeddings"] = jax.ShapeDtypeStruct((B, S, d), jnp.float32)
            else:
                specs["tokens"] = tok
            if self.spec.family == "encdec":
                specs["frames"] = jax.ShapeDtypeStruct(
                    (B, self.spec.frontend_ctx, d), jnp.float32)
        else:  # decode: one token against a seq_len cache
            specs["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            specs["caches"] = self.cache_shapes(B, S)
            specs["cache_len"] = jax.ShapeDtypeStruct((), jnp.int32)
            if self.spec.family == "encdec":
                specs["memory"] = jax.ShapeDtypeStruct(
                    (B, self.spec.frontend_ctx, d), jnp.float32)
        return specs

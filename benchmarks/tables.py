"""One benchmark per paper table (Tab. 1, 2, 3, 5/6, 8) + Fig. 1/3 analog.

Each function yields CSV rows:  table,config,nfe,us_per_call,sw2,mode_rec
Sampler quality is scored by sliced-W2 / mode recovery against ground truth
(see common.py for why this substitutes FID-50k on this container).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax
import jax.numpy as jnp

from repro.sde import VPSDE, CLD, BDM
from repro.core import (sample_gddim, sample_gddim_stochastic, sample_em,
                        sample_heun, sample_ancestral_bdm, sample_rk45_np)
from .common import Bench, paper_mixture, image_mixture, timed


def _row(table, config, nfe, us, metrics) -> str:
    return (f"{table},{config},{nfe},{us:.0f},"
            f"{metrics['sw2']:.4f},{metrics['mode_rec']:.3f}")


# ---------------------------------------------------------------------------
# Table 1 / 5: L_t vs R_t on CLD across NFE and multistep order q
# ---------------------------------------------------------------------------
def table1_Lt_vs_Rt(nfes=(10, 20, 30, 50), qs=(1, 2, 3)) -> Iterator[str]:
    bench = Bench(CLD(), paper_mixture())
    uT = bench.prior()
    for q in qs:
        for kt in ("L", "R"):
            for nfe in nfes:
                ts, co = bench.coeffs(nfe, q=q, kt=kt)
                eps_fn = bench.eps_fn(ts, kt=kt)
                fn = jax.jit(lambda u: sample_gddim(bench.sde, co, eps_fn, u, q=q))
                u0, us = timed(fn, uT)
                yield _row("tab1_tab5", f"Kt={kt}_q={q}", nfe, us,
                           bench.score(u0))


# ---------------------------------------------------------------------------
# Table 2: lambda sweep, gDDIM vs EM (CLD, NFE=50)
# ---------------------------------------------------------------------------
def table2_lambda(nfe=50, lams=(0.0, 0.1, 0.3, 0.5, 1.0)) -> Iterator[str]:
    bench = Bench(CLD(), paper_mixture())
    uT = bench.prior()
    key = jax.random.PRNGKey(7)
    for lam in lams:
        ts, co = bench.coeffs(nfe, q=1, lam=lam)
        eps_fn = bench.eps_fn(ts)
        if lam == 0.0:
            fn = jax.jit(lambda u: sample_gddim(bench.sde, co, eps_fn, u, q=1))
            u0, us = timed(fn, uT)
        else:
            fn = jax.jit(lambda u, k: sample_gddim_stochastic(
                bench.sde, co, eps_fn, u, k))
            u0, us = timed(fn, uT, key)  # staticcheck: disable=SC101 (same noise stream across compared samplers)
        yield _row("tab2", f"gDDIM_lam={lam}", nfe, us, bench.score(u0))
        fn = jax.jit(lambda u, k: sample_em(bench.sde, co, eps_fn, u, k,
                                            lam=max(lam, 1e-6)))
        u0, us = timed(fn, uT, key)  # staticcheck: disable=SC101 (same noise stream across compared samplers)
        yield _row("tab2", f"EM_lam={lam}", nfe, us, bench.score(u0))


# ---------------------------------------------------------------------------
# Table 3: acceleration across DMs (DDPM / BDM / CLD) x samplers x NFE
# ---------------------------------------------------------------------------
def table3_accelerate(nfes=(10, 20, 50, 100)) -> Iterator[str]:
    key = jax.random.PRNGKey(11)
    cases = [("DDPM", VPSDE(), paper_mixture()),
             ("BDM", BDM(data_shape=(8, 8, 1)), image_mixture((8, 8, 1))),
             ("CLD", CLD(), paper_mixture())]
    for dm_name, sde, mix in cases:
        bench = Bench(sde, mix, n_samples=1024)
        uT = bench.prior()
        for nfe in nfes:
            ts, co = bench.coeffs(nfe, q=2)
            eps_fn = bench.eps_fn(ts)
            # gDDIM (multistep q=2)
            fn = jax.jit(lambda u: sample_gddim(bench.sde, co, eps_fn, u, q=2))
            u0, us = timed(fn, uT)
            yield _row("tab3", f"{dm_name}_gDDIM", nfe, us, bench.score(u0))
            # EM baseline (lam=1)
            ts1, co1 = bench.coeffs(nfe, q=1, lam=1.0)
            eps1 = bench.eps_fn(ts1)
            fn = jax.jit(lambda u, k: sample_em(bench.sde, co1, eps1, u, k, lam=1.0))
            u0, us = timed(fn, uT, key)  # staticcheck: disable=SC101 (same noise stream across compared samplers)
            yield _row("tab3", f"{dm_name}_EM", nfe, us, bench.score(u0))
            # 2nd-order Heun (Karras-style, NFE ~ 2N-1 -> use N=nfe//2)
            tsh, coh = bench.coeffs(max(nfe // 2, 2), q=1)
            epsh = bench.eps_fn(tsh)
            fn = jax.jit(lambda u: sample_heun(bench.sde, coh, epsh, u))
            u0, us = timed(fn, uT)
            yield _row("tab3", f"{dm_name}_Heun2", nfe, us, bench.score(u0))
            # BDM ancestral (the original sampler the paper accelerates >20x)
            if dm_name == "BDM":
                fn = jax.jit(lambda u, k: sample_ancestral_bdm(
                    bench.sde, eps_fn, u, np.asarray(ts), k))
                u0, us = timed(fn, uT, key)  # staticcheck: disable=SC101 (same noise stream across compared samplers)
                yield _row("tab3", f"{dm_name}_ancestral", nfe, us, bench.score(u0))
        # RK45 probability flow (host, adaptive — NFE is whatever it takes)
        u0_np, nfe_rk = sample_rk45_np(bench.sde, bench.oracle.score_np,
                                       np.asarray(uT[:256]), rtol=1e-3, atol=1e-3)
        yield _row("tab3", f"{dm_name}_RK45", nfe_rk, 0,
                   bench.score(jnp.asarray(u0_np)))


# ---------------------------------------------------------------------------
# Table 8: predictor-only vs predictor-corrector
# ---------------------------------------------------------------------------
def table8_pc(nfes=(10, 20, 30), qs=(1, 2)) -> Iterator[str]:
    bench = Bench(CLD(), paper_mixture())
    uT = bench.prior()
    for q in qs:
        for nfe in nfes:
            ts, co = bench.coeffs(nfe, q=q)
            eps_fn = bench.eps_fn(ts)
            fn = jax.jit(lambda u: sample_gddim(bench.sde, co, eps_fn, u, q=q))
            u0, us = timed(fn, uT)
            yield _row("tab8", f"P_q={q}", nfe, us, bench.score(u0))
            fn = jax.jit(lambda u: sample_gddim(bench.sde, co, eps_fn, u, q=q,
                                                corrector=True))
            u0, us = timed(fn, uT)
            yield _row("tab8", f"PC_q={q}", 2 * nfe - 1, us, bench.score(u0))


# ---------------------------------------------------------------------------
# Fig. 1/3 analog: eps_theta smoothness along prob-flow solutions (R vs L)
# ---------------------------------------------------------------------------
def fig1_eps_constancy() -> Iterator[str]:
    """Total variation of eps(u(t), t) along exact prob-flow trajectories;
    the paper's core claim is TV(R_t) << TV(L_t) on CLD (Prop 4)."""
    bench = Bench(CLD(), paper_mixture(), n_samples=64)
    nfe = 200
    for kt in ("L", "R"):
        ts, co = bench.coeffs(nfe, q=1, kt=kt, grid="uniform")
        eps_fn = bench.eps_fn(ts, kt=kt)
        u = bench.prior()
        prev = None
        tv = 0.0
        N = co.psi.shape[0]
        for k in range(N):
            i = N - k
            e = eps_fn(u, jnp.int32(i))
            if prev is not None:
                tv += float(jnp.abs(e - prev).mean())
            prev = e
            u = bench.sde.apply(co.psi[k], u) + bench.sde.apply(co.pC[k, 0], e)
        yield f"fig1,eps_TV_Kt={kt},{nfe},0,{tv:.4f},0"


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (CPU wall time is NOT the TPU story; these check
# dispatch overhead and give the interpret-mode cost of each kernel)
# ---------------------------------------------------------------------------
def kernel_micro() -> Iterator[str]:
    from repro.kernels.ei_update.ref import ei_update_ref
    from repro.kernels.attention.ops import blocked_attention
    from repro.kernels.attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    u = jax.random.normal(ks[0], (8, 2, 4096))
    eh = jax.random.normal(ks[1], (2, 8, 2, 4096))
    psi = jax.random.normal(ks[2], (2, 2))
    C = jax.random.normal(ks[3], (2, 2, 2))
    fn = jax.jit(lambda *a: ei_update_ref(*a))
    _, us = timed(fn, u, eh, psi, C)
    _, us = timed(fn, u, eh, psi, C)
    yield f"kernels,ei_update_ref_jit,0,{us:.0f},0,0"
    q = jax.random.normal(ks[0], (1, 512, 8, 64))
    k = jax.random.normal(ks[1], (1, 512, 2, 64))
    v = jax.random.normal(ks[2], (1, 512, 2, 64))
    fn = jax.jit(lambda q, k, v: blocked_attention(q, k, v, causal=True,
                                                   window=None, q_offset=0))
    _, us = timed(fn, q, k, v)
    _, us = timed(fn, q, k, v)
    yield f"kernels,blocked_attention_512,0,{us:.0f},0,0"
    fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    _, us = timed(fn, q, k, v)
    _, us = timed(fn, q, k, v)
    yield f"kernels,ref_attention_512,0,{us:.0f},0,0"

"""Serving throughput: the continuous-batching engines under load.

Rows follow the repo CSV schema (table,config,nfe,us_per_call,sw2,mode_rec);
for serving rows the quality columns carry throughput instead:

  * token rows     — config "<arch>_B<batch>", us_per_call = us per decode
                     round, sw2 column = tokens/s
  * diffusion rows — config "gddim_B<batch>" for homogeneous traffic
                     (every request at the default NFE),
                     "gddim_mix_B<batch>" for heterogeneous sampler-config
                     traffic (a mix of NFE budgets, multistep orders, the
                     corrector and a stochastic lambda through ONE engine),
                     "gddim_fam_mix_B<batch>" for heterogeneous *SDE
                     family* traffic (VPSDE + CLD + BDM co-resident on one
                     engine, each with its own score net), and
                     "gddim_alg_mix_B<batch>" for heterogeneous *sampler
                     algorithm* traffic (gddim + gmm + accel requests
                     co-resident — one compile bucket, the algorithm id
                     masked per slot inside the fused round);
                     nfe = the default sampler NFE, us_per_call = us per
                     serving round, sw2 column = samples/s

Besides the CSV rows, a machine-readable `BENCH_serving.json` is written at
the repo root every time the table runs (via `python -m benchmarks.run
serving`), so the serving perf trajectory is tracked PR-over-PR: one record
per CSV row with explicit field names plus engine counters and the
host/device context.  Every record carries the *deterministic* counters the
CI perf-guard job (`tools/perf_guard.py`) compares against the committed
baseline — timing-free, so the guard is stable on shared runners:

  * `recompiles_after_warmup` — jit cache growth across the measured serve
    (0 for the diffusion engines: the coefficient bank is an argument and
    every (family, corrector) variant is warmed; small fixed values for
    token engines, which meet new width buckets)
  * `rounds` / `polls`        — serving rounds and host polls for the
                                measured request schedule
  * `dispatches`              — step-program dispatches (diffusion; >
                                rounds exactly when families co-reside)
  * `n_prefills` / `prefill_widths` — admission-wave prefill count/widths
  * `bank_bytes` / `bank_restack_rows` — device-resident bytes of the
    engine's factored coefficient bank and the cumulative config-rows the
    CoeffCache (re)packed (diffusion rows; `bank_bytes_dense` records what
    the retired dense PackedBank layout would occupy for the same bank, so
    a reintroduced dense path fails the guard's bank_bytes gate).  The
    `gddim_bank_cifar10` record sizes the same menu at the paper's full
    (32, 32, 3) data shape — pure host-side accounting, where the factored
    form's >= 100x residency cut is the committed baseline.
  * the online record (`gddim_online_B4`) replays a seeded Poisson
    arrival stream on the virtual clock (`serve_stream`): its
    `p50_latency` / `p99_latency` / `goodput_slo` columns come from the
    arrival->admission->completion timestamps in `request_log`, and its
    `n_preemptions` / `n_resumes` / `deadline_misses` counters are exact
    functions of the trace seed, gated EXACT by the guard
  * `variant_hashes` / `n_variants` — on the fam_mix and alg_mix records:
    the jaxpr structural hash of every (family, corrector) round-step
    compile bucket
    (computed by `tools.staticcheck.jaxprcheck.jaxpr_hash`, the same hash
    the `--sanitize` layer prints).  The guard gates the bucket count
    exactly; the hashes let a reviewer see *which* bucket a PR re-traced.
    On the alg_mix record `n_variants == 1` IS the tentpole claim: a
    gddim/gmm/accel mix never leaves the single warmed bucket.
  * the `gddim_alg_quality_*` records (from `benchmarks/quality.py`)
    track sample quality vs NFE per algorithm on the exact-score mixture
    oracle; their `sw2_milli` / `n_samples` / `nfe` fields are gated
    EXACTLY (seeded lockstep CPU sampling — deterministic at a fixed
    platform).

Reduced CPU configs: the numbers are for *relative* tracking (batch scaling,
homogeneous vs mixed traffic, regression against the per-request loop), not
absolute hardware claims.
"""
from __future__ import annotations

import json
import os
import time
from typing import Iterator, List

import numpy as np
import jax

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import (Arrival, DiffusionEngine, Request, SampleRequest,
                         TokenEngine, TraceTraffic, VirtualClock,
                         poisson_trace, serving_metrics)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(REPO_ROOT, "BENCH_serving.json")


def _token_requests(vocab, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    tokens=rng.integers(2, vocab, prompt_len).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


def _write_json(records: List[dict]) -> None:
    doc = {
        "table": "serving",
        "schema": "benchmarks/serving.py (see module docstring)",
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "records": records,
    }
    tmp = BENCH_JSON + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, BENCH_JSON)


def _stats_total(engine) -> int:
    return sum(engine.compile_stats().values())


def _bank_counters(cache) -> dict:
    bank = cache.factored_bank
    return {
        "bank_bytes": bank.nbytes,
        "bank_bytes_dense": bank.dense_equiv_nbytes,
        "bank_restack_rows": cache.bank_restack_rows,
    }


def _bank_residency_record(nfe: int) -> dict:
    """Coefficient-bank residency at the paper's full CIFAR data shape:
    a representative multi-family config menu registered into one
    CoeffCache, then pure byte accounting (no model, no serving) — every
    field deterministic, so the perf guard can gate the factored bank's
    >= 100x cut against the dense-equivalent bytes."""
    from repro.core import CoeffCache, SamplerConfig
    from repro.sde import BDM, CLD, VPSDE

    shape = (32, 32, 3)
    cache = CoeffCache({"vpsde": VPSDE(), "cld": CLD(),
                        "bdm": BDM(data_shape=shape)}, data_shape=shape)
    menu = [SamplerConfig(nfe=nfe),
            SamplerConfig(nfe=max(nfe // 2, 2)),
            SamplerConfig(nfe=nfe, family="cld"),
            SamplerConfig(nfe=nfe, family="cld", corrector=True),
            SamplerConfig(nfe=nfe, family="bdm")]
    for cfg in menu:
        cache.index_of(cfg)
    rec = {"workload": "bank", "config": "gddim_bank_cifar10",
           "data_shape": list(shape), "nfe": nfe, "n_configs": len(cache)}
    rec.update(_bank_counters(cache))
    return rec


def serving_throughput(batches=(1, 4, 8), n_requests=16, prompt_len=16,
                       max_new=16, max_len=64, nfe=10) -> Iterator[str]:
    records: List[dict] = []

    # ---- token decoding: one KV-cache arch + one recurrent-state arch ----
    for arch_name in ("gemma3-1b", "rwkv6-7b"):
        spec = get_arch(arch_name, reduced=True)
        arch = Arch(spec)
        params = arch.init(jax.random.PRNGKey(0))
        for B in batches:
            engine = TokenEngine(arch, params, batch_size=B, max_len=max_len)
            # eos never fires: fixed work per request for comparable rows
            engine.eos_id = -1
            reqs = _token_requests(arch.cfg.vocab, n_requests, prompt_len,
                                   max_new)
            engine.serve(reqs[:B])                     # warmup + compile
            warm_stats = _stats_total(engine)
            n0, s0 = engine.n_tokens_out, engine.n_decode_steps
            p0, w0 = engine.n_polls, len(engine.prefill_widths)
            t0 = time.perf_counter()
            engine.serve(reqs[B:])
            dt = time.perf_counter() - t0
            toks = engine.n_tokens_out - n0
            rounds = max(engine.n_decode_steps - s0, 1)
            us_round = 1e6 * dt / rounds
            widths = list(engine.prefill_widths)[w0:]
            records.append({
                "workload": "token", "config": f"{arch_name}_B{B}",
                "arch": arch_name, "batch": B,
                "us_per_round": round(us_round, 1),
                "tokens_per_s": round(toks / dt, 2),
                "rounds": rounds, "polls": engine.n_polls - p0,
                "recompiles_after_warmup": _stats_total(engine) - warm_stats,
                "n_prefills": len(widths),
                "prefill_widths": widths,
                "n_requests": n_requests - B,
            })
            yield (f"serving,{arch_name}_B{B},0,{us_round:.0f},"
                   f"{toks / dt:.1f},0")

    # ---- gDDIM sampling service: homogeneous vs mixed traffic ----
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    # mixed traffic cycles a preview, a multistep render, a corrector
    # render, and a stochastic sample through ONE engine (one warmed set of
    # compiled step variants, per-slot configs)
    mix = [dict(nfe=max(nfe // 2, 2)),
           dict(nfe=nfe, q=2),
           dict(nfe=nfe, q=2, corrector=True),
           dict(nfe=nfe, lam=0.5)]
    for B in batches:
        for tag, kinds in (("", [dict()]), ("mix_", mix)):
            engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
            engine.serve([SampleRequest(rid=-1 - i, seed=0, **kw)
                          for i, kw in enumerate(kinds)])   # warmup + compile
            warm_stats = _stats_total(engine)
            s0, r0, p0 = engine.n_steps, engine.n_rounds, engine.n_polls
            t0 = time.perf_counter()
            engine.serve([SampleRequest(rid=i, seed=i,
                                        **kinds[i % len(kinds)])
                          for i in range(n_requests)])
            dt = time.perf_counter() - t0
            rounds = max(engine.n_rounds - r0, 1)
            us_step = 1e6 * dt / rounds
            records.append({
                "workload": "diffusion",
                "config": f"gddim_{tag}B{B}", "batch": B, "nfe": nfe,
                "traffic": "mixed" if tag else "homogeneous",
                "us_per_round": round(us_step, 1),
                "samples_per_s": round(n_requests / dt, 3),
                "rounds": rounds, "dispatches": engine.n_steps - s0,
                "polls": engine.n_polls - p0,
                "recompiles_after_warmup": _stats_total(engine) - warm_stats,
                "n_requests": n_requests,
                "n_configs": len(engine.cache),
                **_bank_counters(engine.cache),
            })
            yield (f"serving,gddim_{tag}B{B},{nfe},{us_step:.0f},"
                   f"{n_requests / dt:.2f},0")

    # ---- multi-family gDDIM: VPSDE + CLD + BDM co-resident on ONE engine ----
    fam_specs, fam_params = {}, {}
    for i, (fam, name) in enumerate((("vpsde", "cifar10-ddpm"),
                                     ("cld", "cifar10-cld"),
                                     ("bdm", "cifar10-bdm"))):
        fam_specs[fam] = get_diffusion(name, reduced=True)
        fam_params[fam] = fam_specs[fam].init(jax.random.PRNGKey(i))
    fam_mix = [dict(family="vpsde", nfe=max(nfe // 2, 2)),
               dict(family="cld", nfe=nfe),
               dict(family="bdm", nfe=nfe),
               dict(family="cld", nfe=nfe, corrector=True)]
    B = 4
    n_fam_requests = 8
    engine = DiffusionEngine(fam_specs, fam_params, batch_size=B, nfe=nfe)

    # record one call per (family, corrector) step variant so the jaxpr
    # structural hash of every compile bucket lands in the JSON — the
    # perf guard gates the bucket *count* (n_variants), and the hashes
    # let a reviewer diff exactly which bucket changed PR-over-PR (the
    # same hash tools/staticcheck --sanitize prints; docs/static_analysis.md)
    step_calls: dict = {}

    def _recording(fam, prec, fn):
        def call(*args, **kwargs):
            k = (f"step:{fam},prec={prec},"
                 f"corr={kwargs.get('with_corrector', False)}")
            if k not in step_calls:
                step_calls[k] = (fn, args, kwargs)
            return fn(*args, **kwargs)
        return call

    engine._steps = {(fam, prec): _recording(fam, prec, fn)
                     for (fam, prec), fn in engine._steps.items()}

    engine.serve([SampleRequest(rid=-1 - i, seed=0, **kw)
                  for i, kw in enumerate(fam_mix)])         # warm every
    warm_stats = _stats_total(engine)                       # (fam, corr)
    s0, r0, p0 = engine.n_steps, engine.n_rounds, engine.n_polls
    t0 = time.perf_counter()
    engine.serve([SampleRequest(rid=i, seed=i, **fam_mix[i % len(fam_mix)])
                  for i in range(n_fam_requests)])
    dt = time.perf_counter() - t0
    rounds = max(engine.n_rounds - r0, 1)
    us_step = 1e6 * dt / rounds
    from tools.staticcheck.jaxprcheck import jaxpr_hash
    variant_hashes = {k: jaxpr_hash(fn.trace(*a, **kw).jaxpr)
                      for k, (fn, a, kw) in sorted(step_calls.items())}
    records.append({
        "workload": "diffusion",
        "config": f"gddim_fam_mix_B{B}", "batch": B, "nfe": nfe,
        "variant_hashes": variant_hashes,
        "n_variants": len(variant_hashes),
        "traffic": "multi-family",
        "families": list(engine.families),
        "us_per_round": round(us_step, 1),
        "samples_per_s": round(n_fam_requests / dt, 3),
        "rounds": rounds, "dispatches": engine.n_steps - s0,
        "polls": engine.n_polls - p0,
        "recompiles_after_warmup": _stats_total(engine) - warm_stats,
        "n_requests": n_fam_requests,
        "n_configs": len(engine.cache),
        **_bank_counters(engine.cache),
    })
    yield (f"serving,gddim_fam_mix_B{B},{nfe},{us_step:.0f},"
           f"{n_fam_requests / dt:.2f},0")

    # ---- mixed-algorithm gDDIM: gddim + gmm + accel on ONE engine ----
    # The algorithm axis rides the fused round's int lane like the family
    # id, so every algorithm mix shares the SAME (family, corrector,
    # precision) compile bucket: n_variants stays 1 and
    # recompiles_after_warmup stays 0 — both gated.
    alg_mix = [dict(algorithm="gddim"),
               dict(algorithm="accel"),
               dict(algorithm="gmm", lam=0.5),
               dict(algorithm="gddim", lam=0.5)]
    B = 4
    n_alg_requests = 8
    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    step_calls = {}
    engine._steps = {(fam, prec): _recording(fam, prec, fn)
                     for (fam, prec), fn in engine._steps.items()}
    engine.serve([SampleRequest(rid=-1 - i, seed=0, **kw)
                  for i, kw in enumerate(alg_mix)])          # warmup
    warm_stats = _stats_total(engine)
    s0, r0, p0 = engine.n_steps, engine.n_rounds, engine.n_polls
    t0 = time.perf_counter()
    engine.serve([SampleRequest(rid=i, seed=i, **alg_mix[i % len(alg_mix)])
                  for i in range(n_alg_requests)])
    dt = time.perf_counter() - t0
    rounds = max(engine.n_rounds - r0, 1)
    us_step = 1e6 * dt / rounds
    variant_hashes = {k: jaxpr_hash(fn.trace(*a, **kw).jaxpr)
                      for k, (fn, a, kw) in sorted(step_calls.items())}
    records.append({
        "workload": "diffusion",
        "config": f"gddim_alg_mix_B{B}", "batch": B, "nfe": nfe,
        "variant_hashes": variant_hashes,
        "n_variants": len(variant_hashes),
        "traffic": "mixed-algorithm",
        "algorithms": sorted({kw.get("algorithm", "gddim")
                              for kw in alg_mix}),
        "us_per_round": round(us_step, 1),
        "samples_per_s": round(n_alg_requests / dt, 3),
        "rounds": rounds, "dispatches": engine.n_steps - s0,
        "polls": engine.n_polls - p0,
        "recompiles_after_warmup": _stats_total(engine) - warm_stats,
        "n_requests": n_alg_requests,
        "n_configs": len(engine.cache),
        **_bank_counters(engine.cache),
    })
    yield (f"serving,gddim_alg_mix_B{B},{nfe},{us_step:.0f},"
           f"{n_alg_requests / dt:.2f},0")

    # ---- sample quality vs NFE per algorithm (benchmarks/quality.py) ----
    from .quality import quality_records
    q_records, q_rows = quality_records()
    records.extend(q_records)
    yield from q_rows

    # ---- coefficient-bank residency at the paper's data shape ----
    rec = _bank_residency_record(nfe)
    records.append(rec)
    yield (f"serving,{rec['config']},{nfe},0,"
           f"{rec['bank_bytes_dense'] / max(rec['bank_bytes'], 1):.1f},0")

    # ---- fused-round roofline: achieved vs peak bytes/FLOPs per round ----
    # one pallas launch per post-score-eval commit, analytic single-pass
    # bytes vs the stitched chain's compiled-HLO traffic (roofline.py);
    # `kernel_launches_per_round` and `round_bytes_moved` are EXACT-gated
    from .roofline import serving_round_record
    rec = serving_round_record(nfe=nfe)
    records.append(rec)
    yield (f"serving,{rec['config']},{nfe},0,"
           f"{rec['roofline']['bytes_gap_ratio']:.2f},0")

    # ---- online serving: streaming arrivals, deadlines, preemption ----
    # A seeded Poisson stream replayed on the virtual clock through ONE
    # engine: arrival->admission->completion timestamps become the p50/p99
    # latency and goodput-under-SLO columns, and the preemption counters
    # (n_preemptions / n_resumes / deadline_misses) are pure functions of
    # the trace seed, so the perf guard gates them exactly.
    B = 4
    n_online = 12
    preview = max(nfe // 2, 2)
    engine = DiffusionEngine(spec, params, batch_size=B, nfe=nfe)
    # warmup stream: fill every slot, then a high-priority deadline arrival
    # preempts one — warms admission, the park/restore programs, and both
    # NFE buckets the measured stream draws from
    engine.serve_stream(TraceTraffic(
        [Arrival(0.0, SampleRequest(rid=-1 - i, seed=0)) for i in range(B)]
        + [Arrival(2.0, SampleRequest(rid=-9, seed=0, nfe=preview,
                                      priority=5,
                                      deadline=2.0 + 2.0 * nfe))]))
    warm_stats = _stats_total(engine)
    s0, r0, p0 = engine.n_steps, engine.n_rounds, engine.n_polls
    np0, nr0 = engine.n_preemptions, engine.n_resumes

    def _online_request(i, rng):
        prio = int(rng.integers(0, 3))
        return SampleRequest(
            rid=i, seed=i, nfe=preview if i % 4 == 0 else None,
            priority=prio,
            deadline=None if prio == 0
            else float(rng.integers(2 * nfe, 6 * nfe)))

    trace = poisson_trace(_online_request, n=n_online, rate=0.5, seed=17)
    t0 = time.perf_counter()
    engine.serve_stream(trace, clock=VirtualClock())
    dt = time.perf_counter() - t0
    rounds = max(engine.n_rounds - r0, 1)
    us_step = 1e6 * dt / rounds
    metrics = serving_metrics(engine.request_log)
    records.append({
        "workload": "diffusion",
        "config": f"gddim_online_B{B}", "batch": B, "nfe": nfe,
        "traffic": "online-poisson",
        "us_per_round": round(us_step, 1),
        "samples_per_s": round(n_online / dt, 3),
        "p50_latency": round(metrics["p50_latency"], 3),
        "p99_latency": round(metrics["p99_latency"], 3),
        "goodput_slo": round(metrics["goodput_slo"], 4),
        "deadline_misses": metrics["deadline_misses"],
        "n_preemptions": engine.n_preemptions - np0,
        "n_resumes": engine.n_resumes - nr0,
        "rounds": rounds, "dispatches": engine.n_steps - s0,
        "polls": engine.n_polls - p0,
        "recompiles_after_warmup": _stats_total(engine) - warm_stats,
        "n_requests": n_online,
        "n_configs": len(engine.cache),
        **_bank_counters(engine.cache),
    })
    yield (f"serving,gddim_online_B{B},{nfe},{us_step:.0f},"
           f"{n_online / dt:.2f},0")

    # ---- routed serving: the front-tier over N engine replicas ----
    # The launch harness's canonical scenario (tools/launchgate.py), run
    # in-process: a seeded Poisson trace routed over 2 replicas (one with
    # a deterministic fault window, so health rerouting and backpressure
    # requeues actually fire), each sub-trace drained by its own engine.
    # The route-plan counters (requests_routed / requeues / health_probes
    # / n_shed) are pure functions of (trace, config, seeds) and the
    # perf guard gates them EXACTLY — the same numbers the multi-process
    # CI harness harvests from spawned replicas.
    from tools.launchgate import run_in_process
    record, _, _ = run_in_process()
    records.append(record)
    yield (f"serving,{record['config']},{record['nfe']},"
           f"{record['us_per_round']:.0f},{record['samples_per_s']:.2f},0")

    _write_json(records)

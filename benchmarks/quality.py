"""Sample quality vs NFE per sampler *algorithm* (gddim | gmm | accel).

The ROADMAP's "quality per eval" item, made a tracked number: each
`SamplerConfig.algorithm` is sampled through the PRODUCTION coefficient
path — `CoeffCache` -> `FactoredBank` -> `round_update_ref` (the fused
round's bitwise reference, kernels/round_fused/ref.py) — against the
EXACT mixture score (`repro.sde.mixture.ExactScore`), so the quality
differences measured here come from the update rules alone, not from a
score model.  Everything is seeded and runs lockstep on CPU, so every
row is deterministic at a fixed platform:

  * `sw2`           — sliced 2-Wasserstein to fresh ground-truth draws
                      (the repo's FID stand-in; lower is better)
  * `mode_recovery` — fraction of samples within 5 sigma of a mode
  * `moment_err`    — relative error of the sample mean + covariance
                      against ground-truth draws (the "score-moment"
                      proxy: the GMM reverse kernel is moment-matched,
                      so this column is where a broken `GMM_SCALE` /
                      `GMM_C` pair would show up first)

`quality_records(...)` returns the `gddim_alg_quality_*` records that
`benchmarks/serving.py` merges into `BENCH_serving.json` (perf-guard
gates `sw2_milli` / `n_samples` / `nfe` exactly); `quality_table()` is
the standalone CSV entry registered in `benchmarks/run.py`.

FID hook (GPU): on real hardware, replace the mixture oracle with a
trained checkpoint's `DiffusionSpec.eps_model` and feed the same
per-algorithm sample loop into an FID evaluator (e.g. clean-fid) over
50k samples — the sampling loop below is shape-agnostic, only the
`eps_fn` and the metric change.  The paper's reference points: CLD
FID 2.26 @ 50 NFE, 2.86 @ 27 (Tab. 3).  Not run on this container
(no GPU, no FID dependency baked in).
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import CoeffCache, SamplerConfig
from repro.core.coeffs import _K_fn
from repro.kernels.ei_update.ops import pad_channels
from repro.kernels.round_fused.ref import round_update_ref
from repro.sde import VPSDE, ExactScore

from .common import mode_recovery, paper_mixture, sliced_w2

NOISE_SALT = 0x5EED          # DiffusionEngine._NOISE_SALT


def _sample_via_bank(sde, oracle, cache: CoeffCache, cfg: SamplerConfig,
                     n: int, seed: int) -> np.ndarray:
    """n samples of `cfg` through the factored bank + the fused round's
    reference update — the engine's per-round data flow, run lockstep
    (every slot at the same config and step, so the exact score can be
    evaluated from the grid's precomputed mode constants)."""
    ci = cache.index_of(cfg)
    bank = cache.factored_bank
    co = cache.get(cfg)
    ts = np.asarray(co.ts)
    data_shape = cache.data_shape
    state_shape = sde.state_shape(data_shape)
    kf = sde.packed_k
    K = cache.k_max
    D = int(np.prod(state_shape)) // kf
    Qb = bank.pC_blk.shape[2]
    N = cfg.nfe

    eps_fn, _ = oracle.eps_fn_for_grid(ts, _K_fn(sde, "R"))

    base = jax.random.PRNGKey(seed)
    u = pad_channels(
        sde.canonicalize(sde.prior_sample(base, n, data_shape)), K)
    hist = jnp.zeros((n, Qb, K, D), jnp.float32)
    keys = jnp.broadcast_to(jax.random.fold_in(base, NOISE_SALT), (n, 2))
    k = jnp.zeros((n,), jnp.int32)
    active = jnp.ones((n,), bool)
    cfg_v = jnp.full((n,), ci, jnp.int32)
    zeros = jnp.zeros((n,), jnp.int32)

    for step in range(N):
        kc = jnp.full((n,), step, jnp.int32)
        x_state = sde.decanonicalize(u[:, :kf], data_shape)
        eps_c = sde.canonicalize(eps_fn(x_state, N - step))
        u, hist, k, active = round_update_ref(
            u, hist, k, kc, cfg_v, zeros, zeros, keys, active, bank,
            eps_c, sde=sde, state_shape=state_shape, kf=kf)
    return np.asarray(
        sde.project_data(sde.decanonicalize(u[:, :kf], data_shape)))


def _moment_err(x: np.ndarray, truth: np.ndarray) -> float:
    """Relative mean + covariance error against ground-truth draws."""
    x = np.asarray(x, np.float64).reshape(len(x), -1)
    t = np.asarray(truth, np.float64).reshape(len(truth), -1)
    dm = np.linalg.norm(x.mean(0) - t.mean(0))
    dc = np.linalg.norm(np.cov(x.T) - np.cov(t.T))
    scale = np.linalg.norm(t.mean(0)) + np.linalg.norm(np.cov(t.T))
    return float((dm + dc) / max(scale, 1e-12))


def quality_records(nfes: Tuple[int, ...] = (5, 10, 20),
                    n_samples: int = 512, seed: int = 0
                    ) -> Tuple[List[dict], List[str]]:
    """(json_records, csv_rows) for the per-algorithm quality sweep on the
    VPSDE ring mixture.  Deterministic configs compare gddim vs accel;
    stochastic (lam=0.5) configs compare gddim vs gmm — each pair shares
    its Stage-I quadrature, so the rows isolate the update rule."""
    sde = VPSDE()
    mix = paper_mixture()
    oracle = ExactScore(sde, mix)
    cache = CoeffCache({"vpsde": sde}, data_shape=mix.data_shape)
    truth = np.asarray(mix.sample(jax.random.PRNGKey(seed + 1), n_samples))

    menu = [("gddim", 0.0), ("accel", 0.0),
            ("gddim", 0.5), ("gmm", 0.5)]
    records: List[dict] = []
    rows: List[str] = []
    for nfe in nfes:
        for alg, lam in menu:
            cfg = SamplerConfig(nfe=nfe, lam=lam, algorithm=alg)
            x = _sample_via_bank(sde, oracle, cache, cfg, n_samples, seed)
            sw2 = sliced_w2(x, truth)
            rec = {
                "workload": "quality",
                "config": f"gddim_alg_quality_{alg}"
                          f"{'_lam' if lam else ''}_nfe{nfe}",
                "algorithm": alg, "nfe": nfe, "lam": lam,
                "n_samples": n_samples,
                "sw2": round(sw2, 4),
                # integer-quantized copy for the EXACT perf-guard gate
                # (full-precision floats would be fragile to format churn)
                "sw2_milli": int(round(sw2 * 1000)),
                "mode_recovery": round(mode_recovery(x, mix), 3),
                "moment_err": round(_moment_err(x, truth), 4),
            }
            records.append(rec)
            rows.append(f"serving,{rec['config']},{nfe},0,"
                        f"{rec['sw2']:.4f},{rec['mode_recovery']:.3f}")
    return records, rows


def quality_table() -> Iterator[str]:
    """Standalone CSV entry (`python -m benchmarks.run quality`) — same
    sweep, no JSON side effects (the serving table owns the JSON)."""
    _, rows = quality_records()
    yield from rows

"""Shared benchmark machinery.

The paper scores CIFAR10 FID-50k; on this container every table is
reproduced on analytically tractable data instead (DESIGN.md §1): a
well-separated 2-D Gaussian mixture (the paper's own Fig. 4 toy) pushed
through each SDE with the EXACT score, so sampler quality is isolated from
score-model quality.  Metric: sliced Wasserstein-2 against fresh
ground-truth draws (lower is better, same ordering semantics as FID).
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import numpy as np
import jax

from repro.sde import GaussianMixture, ExactScore
from repro.core import build_sampler_coeffs, time_grid


def paper_mixture(d: int = 2, modes: int = 8, radius: float = 4.0,
                  std: float = 0.05) -> GaussianMixture:
    """Ring of well-separated modes (the paper's challenging 2-D example)."""
    ang = np.linspace(0, 2 * np.pi, modes, endpoint=False)
    means = np.zeros((modes, d))
    means[:, 0] = radius * np.cos(ang)
    means[:, 1] = radius * np.sin(ang)
    return GaussianMixture(means, np.full(modes, std), np.ones(modes))


def image_mixture(shape=(8, 8, 1), modes: int = 4, std: float = 0.05) -> GaussianMixture:
    """Low-res 'image' mixture for the BDM benchmarks (DCT needs 2-D data)."""
    rng = np.random.default_rng(0)
    means = rng.uniform(-1, 1, size=(modes,) + shape)
    return GaussianMixture(means, np.full(modes, std), np.ones(modes))


def sliced_w2(x: np.ndarray, y: np.ndarray, n_proj: int = 128,
              seed: int = 0) -> float:
    """Sliced 2-Wasserstein distance between point clouds (flattened)."""
    x = np.asarray(x, np.float64).reshape(len(x), -1)
    y = np.asarray(y, np.float64).reshape(len(y), -1)
    d = x.shape[1]
    rng = np.random.default_rng(seed)
    proj = rng.standard_normal((d, n_proj))
    proj /= np.linalg.norm(proj, axis=0, keepdims=True)
    xp = np.sort(x @ proj, axis=0)
    yp = np.sort(y @ proj, axis=0)
    n = min(len(xp), len(yp))
    xq = xp[np.linspace(0, len(xp) - 1, n).astype(int)]
    yq = yp[np.linspace(0, len(yp) - 1, n).astype(int)]
    return float(np.sqrt(np.mean((xq - yq) ** 2)))


def mode_recovery(x: np.ndarray, mix: GaussianMixture, tol_sigmas: float = 5.0
                  ) -> float:
    """Fraction of samples within tol*std of their nearest mode."""
    x = np.asarray(x).reshape(len(x), -1)
    mu = mix.means.reshape(len(mix.means), -1)
    d = np.linalg.norm(x[:, None] - mu[None], axis=-1)
    near = d.min(1)
    std = mix.stds.mean() * np.sqrt(x.shape[1])
    return float((near < tol_sigmas * std).mean())


class Bench:
    """One (sde, mixture) benchmark context with exact-score sampling."""

    def __init__(self, sde, mix: GaussianMixture, n_samples: int = 2048,
                 seed: int = 0):
        self.sde = sde
        self.mix = mix
        self.oracle = ExactScore(sde, mix)
        self.n = n_samples
        self.key = jax.random.PRNGKey(seed)
        self.truth = np.asarray(mix.sample(jax.random.PRNGKey(seed + 1), n_samples))

    def coeffs(self, nfe: int, q: int = 2, lam: float = 0.0, kt: str = "R",
               grid: str = "quadratic"):
        ts = time_grid(self.sde, nfe, grid)
        return ts, build_sampler_coeffs(self.sde, ts, q=q, lam=lam, kt=kt)

    def eps_fn(self, ts, kt: str = "R"):
        from repro.core.coeffs import _K_fn
        return self.oracle.eps_fn_for_grid(ts, _K_fn(self.sde, kt))[0]

    def prior(self):
        return self.sde.prior_sample(self.key, self.n, self.mix.data_shape)

    def score(self, u0) -> Dict[str, float]:
        x = np.asarray(self.sde.project_data(u0))
        return {"sw2": sliced_w2(x, self.truth),
                "mode_rec": mode_recovery(x, self.mix)}


def timed(fn: Callable, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    return out, (time.time() - t0) * 1e6

"""Benchmark aggregator: one function per paper table (see tables.py).

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run tab3 fig1  # subset

Output CSV: table,config,nfe,us_per_call,sw2,mode_recovery
(sw2 = sliced Wasserstein-2 to ground truth; the FID stand-in, lower=better)
"""
import sys

from . import quality
from . import tables
from . import serving


ALL = {
    "tab1": tables.table1_Lt_vs_Rt,
    "tab2": tables.table2_lambda,
    "tab3": tables.table3_accelerate,
    "tab8": tables.table8_pc,
    "fig1": tables.fig1_eps_constancy,
    "kernels": tables.kernel_micro,
    "serving": serving.serving_throughput,
    "quality": quality.quality_table,
}


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    names = argv or list(ALL)
    print("table,config,nfe,us_per_call,sw2,mode_recovery")
    for n in names:
        for row in ALL[n]():
            print(row, flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

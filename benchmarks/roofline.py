"""Roofline report: aggregates the dry-run JSONL into the EXPERIMENTS.md
tables (per arch x shape x mesh: three terms, bottleneck, MODEL/HLO ratio,
roofline fraction).

`--serving` switches to the *serving-round* mode (PR 8): instead of
aggregating dry-run records it builds the diffusion engine's round update
at the benchmark shapes and reports achieved vs peak bytes/FLOPs per
round — the fused megakernel's analytic single-pass traffic
(`kernels/round_fused.ops.fused_round_cost`, one launch) against the
compiled-HLO byte traffic of the pre-fusion XLA-stitched chain
(`hlo_analysis.hlo_program_stats`), i.e. the measured roofline gap the
fusion closes.  The same record is appended to `BENCH_serving.json` by
`python -m benchmarks.run serving`, where `kernel_launches_per_round` and
`round_bytes_moved` are EXACT-gated by tools/perf_guard.py."""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Tuple



def load(path: str) -> Tuple[List[dict], int]:
    """Parse a dry-run JSONL; returns (records, n_skipped).  Malformed
    lines are *counted*, not silently dropped — a truncated results file
    (killed run, concurrent writer) used to thin the report without a
    trace, which reads as "that shape was never measured"."""
    out: List[dict] = []
    skipped = 0
    if not os.path.exists(path):
        return out, skipped
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    skipped += 1
    # keep the LAST record per (arch, shape, mesh, tag) — reruns supersede
    dedup: Dict[tuple, dict] = {}
    for r in out:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"), r.get("tag", ""))] = r
    return list(dedup.values()), skipped


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(records: List[dict], mesh: str | None = None) -> str:
    rows = []
    hdr = ("| arch | shape | mesh | T_comp | T_mem | T_coll | bottleneck | "
           "MODEL/HLO | roofline frac | HBM/dev |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in sorted(records, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip | skip | skip | — ({r['reason'][:40]}…) | - | - | - |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | "
                        f"ERR | ERR | ERR | {str(r.get('error'))[:40]} | - | - | - |")
            continue
        ro = r["roofline"]
        mem = r.get("memory") or {}
        hbm = mem.get("total_bytes", mem.get("temp_bytes", 0))
        ratio = r.get("useful_flop_ratio")
        frac = r.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | "
            f"{fmt_s(ro['t_collective_s'])} | {ro['bottleneck']} | "
            f"{ratio:.2f} | {frac:.3f} | {hbm/2**30:.1f}GiB |"
            if ratio is not None and frac is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | "
            f"{fmt_s(ro['t_collective_s'])} | {ro['bottleneck']} | - | - | "
            f"{hbm/2**30:.1f}GiB |")
    return "\n".join(rows)


def pick_hillclimb(records: List[dict]) -> dict:
    """worst roofline fraction / most collective-bound / paper-representative."""
    ok = [r for r in records if r.get("status") == "ok"
          and r.get("roofline_fraction") is not None]
    train = [r for r in ok if r["shape"] == "train_4k"]
    out = {}
    if train:
        out["worst_fraction"] = min(train, key=lambda r: r["roofline_fraction"])
    coll = [r for r in ok
            if r["roofline"]["bottleneck"] == "collective"]
    if coll:
        out["most_collective"] = max(
            coll, key=lambda r: r["roofline"]["t_collective_s"])
    diff = [r for r in records if str(r.get("arch", "")).startswith("cifar10")]
    if diff:
        out["paper_representative"] = diff[0]
    return out


def serving_round_record(nfe: int = 10, batch: int = 4) -> dict:
    """The serving-round roofline record: one fused launch's analytic
    bytes/FLOPs vs the pre-fusion stitched chain's compiled-HLO traffic,
    plus the peak-rate terms, at the serving benchmark's reduced CIFAR
    shapes.  Every gated field is a pure function of static shapes:

      * `kernel_launches_per_round` — pallas_call count in the traced
        fused update (the tentpole's contract: ONE post-score-eval
        launch; the corrector's predict launch runs before the eval)
      * `round_bytes_moved` / `round_flops` — `fused_round_cost`'s
        single-pass model (each stream touched exactly once)
      * `stitched_bytes_moved` / `stitched_flops` — `hlo_program_stats`
        over the jit-compiled stitched update: what the old chain's
        fusion boundaries actually stream
      * `roofline` — achieved intensity vs machine balance and the
        per-round time bounds at peak HBM/FLOP rates, fused vs stitched;
        `bytes_gap_ratio` is the roofline gap the fusion closes
    """
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs import get_diffusion
    from repro.core import SamplerConfig
    from repro.launch import hlo_analysis
    from repro.serve import DiffusionEngine, SampleRequest
    from repro.kernels.round_fused import ops as rf_ops
    from tools.staticcheck.pallas_check import find_pallas_eqns

    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    engine = DiffusionEngine(spec, params, batch_size=batch, nfe=nfe)
    engine.cache.index_of(SamplerConfig(nfe=nfe, q=2))   # Qb=2 bucket
    engine._refresh_bank()
    bank, state = engine._bank, engine.state
    sde = spec.sde
    kf = sde.packed_k
    B, K, D = state.u.shape
    Qb = state.hist.shape[1]
    state_shape = sde.state_shape(tuple(spec.data_shape))
    kc = jnp.zeros((B,), jnp.int32)
    eps_c = jnp.zeros((B, kf, D), jnp.float32)

    def update(impl):
        def fn(u, hist, k, cfg, fam, prec, keys, active, bank, eps_c):
            kcl = jnp.clip(k, 0, bank.n_steps[cfg] - 1)
            return rf_ops.round_update(
                u, hist, k, kcl, cfg, fam, prec, keys, active, bank,
                eps_c, sde=sde, state_shape=state_shape, kf=kf, impl=impl)
        return fn

    args = (state.u, state.hist, state.k, state.cfg, state.fam, state.prec,
            state.keys, state.active, bank, eps_c)

    # the old chain, as XLA compiles it on this backend
    stitched = jax.jit(update("ref")).lower(*args).compile()
    s_stats = hlo_analysis.hlo_program_stats(stitched.as_text())

    # the fused kernel: launch count from the trace, bytes from the
    # analytic single-pass model (the Mosaic kernel's contract)
    jaxpr = jax.make_jaxpr(update("pallas"))(*args)
    launches = len(find_pallas_eqns(jaxpr))
    cost = rf_ops.fused_round_cost(
        B=B, K=K, Qb=Qb, kf=kf, D=D, pool_rows=bank.diag.shape[0])

    t_comp = cost["flops"] / hlo_analysis.PEAK_FLOPS
    t_mem = cost["bytes_moved"] / hlo_analysis.HBM_BW
    s_mem = s_stats["bytes"] / hlo_analysis.HBM_BW
    balance = hlo_analysis.PEAK_FLOPS / hlo_analysis.HBM_BW
    intensity = cost["flops"] / max(cost["bytes_moved"], 1)
    return {
        "workload": "diffusion",
        "config": "gddim_round_roofline",
        "batch": B, "nfe": nfe, "K": K, "Qb": Qb, "kf": kf, "D": D,
        "kernel_launches_per_round": launches,
        "round_bytes_moved": cost["bytes_moved"],
        "round_flops": cost["flops"],
        "stitched_bytes_moved": int(s_stats["bytes"]),
        "stitched_flops": int(s_stats["flops"]),
        "roofline": {
            "bytes_gap_ratio": round(s_stats["bytes"]
                                     / max(cost["bytes_moved"], 1), 3),
            "intensity_flop_per_byte": round(intensity, 4),
            "machine_balance_flop_per_byte": round(balance, 1),
            "bottleneck": "memory" if intensity < balance else "compute",
            "t_mem_s_fused": t_mem, "t_mem_s_stitched": s_mem,
            "t_comp_s": t_comp,
        },
    }


def main(argv=None) -> int:
    argv = list(argv if argv is not None else sys.argv[1:])
    if "--serving" in argv:
        rec = serving_round_record()
        print(json.dumps(rec, indent=2, sort_keys=True))
        return 0
    paths = argv or ["results/dryrun_single.jsonl",
                     "results/dryrun_multi.jsonl"]
    recs = []
    n_skipped = 0
    for p in paths:
        r, skipped = load(p)
        recs += r
        if skipped:
            print(f"WARNING: {p}: skipped {skipped} malformed JSONL "
                  f"line(s)", file=sys.stderr)
        n_skipped += skipped
    print(table(recs))
    if n_skipped:
        print(f"\n{n_skipped} malformed line(s) skipped — see stderr")
    picks = pick_hillclimb(recs)
    print()
    for k, r in picks.items():
        print(f"hillclimb[{k}]: {r['arch']} x {r['shape']} x {r['mesh']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Roofline report: aggregates the dry-run JSONL into the EXPERIMENTS.md
tables (per arch x shape x mesh: three terms, bottleneck, MODEL/HLO ratio,
roofline fraction)."""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List



def load(path: str) -> List[dict]:
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    # keep the LAST record per (arch, shape, mesh, tag) — reruns supersede
    dedup: Dict[tuple, dict] = {}
    for r in out:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"), r.get("tag", ""))] = r
    return list(dedup.values())


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def table(records: List[dict], mesh: str | None = None) -> str:
    rows = []
    hdr = ("| arch | shape | mesh | T_comp | T_mem | T_coll | bottleneck | "
           "MODEL/HLO | roofline frac | HBM/dev |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for r in sorted(records, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skip | skip | skip | — ({r['reason'][:40]}…) | - | - | - |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh','?')} | "
                        f"ERR | ERR | ERR | {str(r.get('error'))[:40]} | - | - | - |")
            continue
        ro = r["roofline"]
        mem = r.get("memory") or {}
        hbm = mem.get("total_bytes", mem.get("temp_bytes", 0))
        ratio = r.get("useful_flop_ratio")
        frac = r.get("roofline_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | "
            f"{fmt_s(ro['t_collective_s'])} | {ro['bottleneck']} | "
            f"{ratio:.2f} | {frac:.3f} | {hbm/2**30:.1f}GiB |"
            if ratio is not None and frac is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(ro['t_compute_s'])} | {fmt_s(ro['t_memory_s'])} | "
            f"{fmt_s(ro['t_collective_s'])} | {ro['bottleneck']} | - | - | "
            f"{hbm/2**30:.1f}GiB |")
    return "\n".join(rows)


def pick_hillclimb(records: List[dict]) -> dict:
    """worst roofline fraction / most collective-bound / paper-representative."""
    ok = [r for r in records if r.get("status") == "ok"
          and r.get("roofline_fraction") is not None]
    train = [r for r in ok if r["shape"] == "train_4k"]
    out = {}
    if train:
        out["worst_fraction"] = min(train, key=lambda r: r["roofline_fraction"])
    coll = [r for r in ok
            if r["roofline"]["bottleneck"] == "collective"]
    if coll:
        out["most_collective"] = max(
            coll, key=lambda r: r["roofline"]["t_collective_s"])
    diff = [r for r in records if str(r.get("arch", "")).startswith("cifar10")]
    if diff:
        out["paper_representative"] = diff[0]
    return out


def main(argv=None) -> int:
    paths = argv or sys.argv[1:] or ["results/dryrun_single.jsonl",
                                     "results/dryrun_multi.jsonl"]
    recs = []
    for p in paths:
        recs += load(p)
    print(table(recs))
    picks = pick_hillclimb(recs)
    print()
    for k, r in picks.items():
        print(f"hillclimb[{k}]: {r['arch']} x {r['shape']} x {r['mesh']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

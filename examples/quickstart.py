"""The paper in one script (CPU, ~2 min).

Trains a small MLP score network on the paper's 2-D mixture under CLD with
the gDDIM parameterization K_t = R_t (Eq. 77 HSM loss, both channels
supervised — Eq. 80), then samples with:

  * deterministic gDDIM (exponential multistep, q = 2)    [the paper]
  * stochastic gDDIM (lambda = 0.5)                       [Eq. 22]
  * Euler-Maruyama baseline                               [what it beats]

and reports sliced-W2 to ground truth at NFE in {10, 50}.

    PYTHONPATH=src:. python examples/quickstart.py

`--smoke` (CI) shrinks training to a few hundred steps and samples at one
NFE — same code path end to end, seconds instead of minutes.
"""
import argparse
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro.sde import CLD, GaussianMixture
from repro.core import build_sampler_coeffs, time_grid, sample_gddim, \
    sample_gddim_stochastic, sample_em
from repro.models.score_net import MLPScoreCfg, mlp_score_init, mlp_score_apply
from repro.train import losses
from repro.optim.adamw import AdamWCfg, adamw_init, adamw_update
from benchmarks.common import sliced_w2, mode_recovery


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer train steps, one NFE")
    args = ap.parse_args(argv)
    train_steps = 300 if args.smoke else 2500
    nfes = (10,) if args.smoke else (10, 50)
    n_eval = 1000 if args.smoke else 4000

    key = jax.random.PRNGKey(0)
    sde = CLD()
    ang = np.linspace(0, 2 * np.pi, 4, endpoint=False)
    mix = GaussianMixture(np.stack([2.5 * np.cos(ang), 2.5 * np.sin(ang)], -1),
                          np.full(4, 0.08), np.ones(4))

    # ---- train (DSM/HSM with K_t = R_t; both eps channels supervised) -----
    cfg = MLPScoreCfg(state_shape=(2, 2), hidden=192, n_blocks=3)
    params = mlp_score_init(key, cfg)
    opt_cfg = AdamWCfg(lr=2e-3, warmup_steps=50, total_steps=train_steps,
                       weight_decay=0.0)
    opt = adamw_init(params, opt_cfg)
    tables = losses.build_perturb_tables(sde, kt="R")

    @jax.jit
    def step(params, opt, x0, k):
        def loss_fn(p):
            return losses.dsm_loss(sde, tables,
                                   lambda u, t: mlp_score_apply(p, cfg, u, t),
                                   x0, k)
        l, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, opt, params, opt_cfg)
        return params, opt, l

    print("training MLP score net on CLD (K_t = R_t, HSM) ...")
    for i in range(train_steps):
        k1, k2, key = jax.random.split(key, 3)
        x0 = mix.sample(k1, 256)
        params, opt, l = step(params, opt, x0, k2)
        if i % 500 == 0:
            print(f"  step {i:4d}  dsm-loss {float(l):.4f}")

    # ---- sample --------------------------------------------------------------
    truth = np.asarray(mix.sample(jax.random.PRNGKey(42), n_eval))
    sw2_seen = []
    print(f"\n{'sampler':28s} {'NFE':>4s} {'sw2':>8s} {'modes':>6s}")
    for nfe in nfes:
        ts = time_grid(sde, nfe)
        eps_fn = losses.make_eps_fn_from_model(
            sde, lambda u, t: mlp_score_apply(params, cfg, u, t), ts)
        uT = sde.prior_sample(jax.random.PRNGKey(7), n_eval, (2,))

        def report(label, x):
            sw2 = sliced_w2(np.asarray(x), truth)
            sw2_seen.append(sw2)
            print(f"{label:28s} {nfe:4d} {sw2:8.4f} "
                  f"{mode_recovery(np.asarray(x), mix):6.2f}")

        for q in (1, 2):
            co = build_sampler_coeffs(sde, ts, q=q)
            x = sde.project_data(sample_gddim(sde, co, eps_fn, uT, q=q))
            report("gDDIM det (q=%d)" % q, x)

        co_s = build_sampler_coeffs(sde, ts, q=1, lam=0.5)
        x = sde.project_data(sample_gddim_stochastic(
            sde, co_s, eps_fn, uT, jax.random.PRNGKey(9)))
        report("gDDIM stoch (lam=0.5)", x)

        co_em = build_sampler_coeffs(sde, ts, q=1, lam=1.0)
        x = sde.project_data(sample_em(sde, co_em, eps_fn, uT,
                                       jax.random.PRNGKey(9), lam=1.0))
        report("Euler-Maruyama (lam=1)", x)

    # smoke gate: a short run can't hit the paper's numbers, but every
    # sampler must at least produce finite samples
    if not np.all(np.isfinite(sw2_seen)):
        print("FAIL: non-finite sliced-W2 — the sampling path is broken",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

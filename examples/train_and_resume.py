"""End-to-end fault-tolerant LM training driver walkthrough:
train gemma3-1b (reduced) -> checkpoint -> kill -> resume exactly.

    PYTHONPATH=src python examples/train_and_resume.py
"""
import sys, tempfile
sys.path.insert(0, "src")

from repro.launch import train as train_mod


def main():
    with tempfile.TemporaryDirectory() as ck:
        print("== phase 1: 30 steps with async checkpoints every 10")
        train_mod.main(["--arch", "gemma3-1b", "--reduced", "--steps", "30",
                        "--batch", "8", "--seq-len", "64", "--lr", "1e-3",
                        "--ckpt-dir", ck, "--ckpt-every", "10"])
        print("== phase 2: 'restart after preemption' -> resumes at 30, runs to 60")
        train_mod.main(["--arch", "gemma3-1b", "--reduced", "--steps", "60",
                        "--batch", "8", "--seq-len", "64", "--lr", "1e-3",
                        "--ckpt-dir", ck, "--resume"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Tab. 3 flagship claim, runnable: gDDIM accelerates BDM >20x over
its original ancestral sampler (exact-score 8x8 image mixture, CPU ~1 min).

    PYTHONPATH=src:. python examples/bdm_acceleration.py
"""
import sys
sys.path.insert(0, "src"); sys.path.insert(0, ".")

import numpy as np
import jax

from repro.sde import BDM
from repro.core import build_sampler_coeffs, time_grid, sample_gddim, \
    sample_ancestral_bdm
from benchmarks.common import Bench, image_mixture


def main():
    bench = Bench(BDM(data_shape=(8, 8, 1)), image_mixture((8, 8, 1)),
                  n_samples=1024)
    uT = bench.prior()
    print(f"{'sampler':22s} {'NFE':>5s} {'sw2':>8s}")
    rows = []
    for nfe in (10, 20, 50, 100, 200):
        ts, co = bench.coeffs(nfe, q=2)
        eps_fn = bench.eps_fn(ts)
        x = sample_gddim(bench.sde, co, eps_fn, uT, q=2)
        s = bench.score(x)["sw2"]
        rows.append(("gDDIM(q=2)", nfe, s))
        x = sample_ancestral_bdm(bench.sde, eps_fn, uT, np.asarray(ts),
                                 jax.random.PRNGKey(0))
        rows.append(("ancestral (original)", nfe, bench.score(x)["sw2"]))
    for name, nfe, s in rows:
        print(f"{name:22s} {nfe:5d} {s:8.4f}")
    g10 = [s for n, f, s in rows if n.startswith("gDDIM") and f == 10][0]
    anc100 = [s for n, f, s in rows if n.startswith("ancestral") and f == 100][0]
    anc200 = [s for n, f, s in rows if n.startswith("ancestral") and f == 200][0]
    print(f"\ngDDIM @ 10 NFE ({g10:.4f}) beats ancestral @ 100 NFE "
          f"({anc100:.4f}) and matches ancestral @ 200 NFE ({anc200:.4f}) "
          f"-> 10-20x fewer NFE for comparable quality (paper Tab. 3)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

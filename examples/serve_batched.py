"""Continuous-batching walkthrough: the `repro.serve` API.

Two workloads ride the same scheduler/slot-table machinery:

1. Token decoding (`repro.serve.TokenEngine`) over the rwkv6 arch
   (O(1)/token recurrent state) and the gemma3 arch (GQA KV cache with
   sliding-window layers):

       engine  = TokenEngine(arch, params, batch_size=4, max_len=64)
       results = engine.serve([Request(rid=0, tokens=prompt, max_new=8), ...])
       # results[rid] -> np.ndarray of generated token ids

   Under the hood each admission wave runs ONE batched prefill
   (`make_prefill_step`) for a same-length group, scatters the resulting
   cache rows into the admitted slots only, and the decode loop passes a
   per-slot position vector so a freshly refilled slot decodes at its own
   absolute position.  A request's output is bitwise identical whether it
   runs alone or interleaved with neighbours (tests/test_serve_engine.py).

2. gDDIM sampling as a service (`repro.serve.DiffusionEngine`): slots are
   samples, the per-slot position is the sampler step index k, and one
   jitted `make_diffusion_serve_step` advances slots at different k in the
   same batch — the paper's cheap-NFE sampler behind a serving interface:

       engine  = DiffusionEngine(spec, params, batch_size=4, nfe=20)
       results = engine.serve([SampleRequest(rid=0, seed=0), ...])
       # results[rid] -> np.ndarray sample in data space

Run:
    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import DiffusionEngine, Request, SampleRequest, TokenEngine


def serve_tokens(arch_name: str) -> None:
    print(f"== token engine: {arch_name} (reduced config)")
    spec = get_arch(arch_name, reduced=True)
    arch = Arch(spec)
    params = arch.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # 6 requests through 4 slots: the last two are admitted into slots
    # retired by earlier requests (continuous batching)
    requests = [Request(rid=i,
                        tokens=rng.integers(2, arch.cfg.vocab, 8).astype(np.int32),
                        max_new=8)
                for i in range(6)]
    engine = TokenEngine(arch, params, batch_size=4, max_len=32)
    results = engine.serve(requests)
    for rid in sorted(results):
        print(f"  req{rid}: {results[rid].tolist()}")
    print(f"  {engine.n_prefill_calls} prefill calls, "
          f"{engine.n_decode_steps} decode rounds, "
          f"compile={engine.compile_stats()}")


def serve_samples() -> None:
    print("== diffusion engine: cifar10-ddpm (reduced config)")
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    engine = DiffusionEngine(spec, params, batch_size=4, nfe=10)
    results = engine.serve([SampleRequest(rid=i, seed=i) for i in range(6)])
    for rid in sorted(results):
        x = results[rid]
        print(f"  sample{rid}: shape={x.shape} mean={x.mean():+.3f} "
              f"std={x.std():.3f}")
    print(f"  {engine.n_steps} gDDIM rounds, compile={engine.compile_stats()}")


def main():
    for arch in ("rwkv6-7b", "gemma3-1b"):
        serve_tokens(arch)
    serve_samples()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Continuous-batching walkthrough: the `repro.serve` API.

Two workloads ride the same scheduler/slot-table machinery:

1. Token decoding (`repro.serve.TokenEngine`) over the rwkv6 arch
   (O(1)/token recurrent state) and the gemma3 arch (GQA KV cache with
   sliding-window layers):

       engine  = TokenEngine(arch, params, batch_size=4, max_len=64)
       results = engine.serve([Request(rid=0, tokens=prompt, max_new=8), ...])
       # results[rid] -> np.ndarray of generated token ids

   Under the hood each admission wave runs ONE batched prefill
   (`make_prefill_step`, width-bucketed to the wave's power-of-two size)
   for a same-length group and scatters the resulting cache rows into the
   admitted slots only.  Per-slot metadata — positions, output rings,
   active masks — lives on device in a `TokenState` pytree updated inside
   the donated round step, so the steady-state loop moves nothing
   host->device; the host polls a small done mask every few rounds.  A
   request's output is bitwise identical whether it runs alone or
   interleaved with neighbours (tests/test_serve_engine.py).

2. gDDIM sampling as a service (`repro.serve.DiffusionEngine`): slots are
   samples, the per-slot position is the sampler step index k, and each
   request carries its *own sampler config* — NFE budget, multistep order
   q, Eq. 45 corrector, stochasticity lambda.  One jitted
   `make_diffusion_round_step` advances a device-resident `DiffusionState`
   whose slots sit at different k AND different configs in the same batch,
   gathering each slot's coefficient rows from a stacked, bucket-padded
   `CoeffBank` built once per distinct config by the host-side
   `CoeffCache`:

       engine  = DiffusionEngine(spec, params, batch_size=4, nfe=20)
       results = engine.serve([
           SampleRequest(rid=0, seed=0),                  # engine default
           SampleRequest(rid=1, seed=1, nfe=5),           # fast preview
           SampleRequest(rid=2, seed=2, nfe=20, q=2, corrector=True),
           SampleRequest(rid=3, seed=3, nfe=10, lam=0.5), # stochastic
       ])
       # results[rid] -> np.ndarray sample in data space

   The paper's point — one trained score network supports the whole
   sampler family (Eqs. 19/22/45) — behind one hot, batched program.

3. Multi-family serving: the same `DiffusionEngine` built with ordered
   `{family: spec}` / `{family: params}` mappings serves VPSDE + CLD + BDM
   traffic from ONE slot pool — every slot lives in the canonical packed
   (K, D) layout (CLD's (x, v) channels set K=2; BDM slots are DCT
   coefficients riding the dct2 kernel path), each family's score net
   stays device-resident, and a round dispatches one compiled variant per
   (family, corrector) class present in the batch:

       engine = DiffusionEngine({"vpsde": sv, "cld": sc, "bdm": sb},
                                {"vpsde": pv, "cld": pc, "bdm": pb},
                                batch_size=4, nfe=10)
       results = engine.serve([
           SampleRequest(rid=0, seed=0),                   # default: vpsde
           SampleRequest(rid=1, seed=1, family="cld", nfe=8),
           SampleRequest(rid=2, seed=2, family="bdm", nfe=6),
       ])

   Every request is bitwise identical to a solo single-family engine run
   (tests/test_serve_engine.py).

4. The wire-level request API and the router front-tier: every request
   above is a `repro.serve.ServeRequest` (`Request` / `SampleRequest` are
   thin aliases) — frozen, schema-versioned, and exactly JSON
   round-trippable (`from_wire(to_wire(r)) == r`), which is what lets a
   `Router` split an arrival trace over N engine replicas across process
   boundaries with results bitwise identical to one engine
   (docs/serving.md, "Multi-host serving and the router front-tier"):

       router = Router([ReplicaSpec(index=0), ReplicaSpec(index=1)])
       results, plan = router.serve(trace, [engine_a, engine_b])

Both engines also take `mesh=` (repro.launch.mesh.make_local_mesh) to
shard the slot batch over a data-parallel device mesh with bitwise-
identical results — see docs/serving.md and tests/test_serve_mesh.py.

Run:
    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np
import jax

from repro.configs import get_arch, get_diffusion
from repro.models.registry import Arch
from repro.serve import (Arrival, DiffusionEngine, ReplicaSpec, Request,
                         Router, SampleRequest, ServeRequest, TokenEngine,
                         TraceTraffic)


def serve_tokens(arch_name: str) -> None:
    print(f"== token engine: {arch_name} (reduced config)")
    spec = get_arch(arch_name, reduced=True)
    arch = Arch(spec)
    params = arch.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # 6 requests through 4 slots: the last two are admitted into slots
    # retired by earlier requests (continuous batching)
    requests = [Request(rid=i,
                        tokens=rng.integers(2, arch.cfg.vocab, 8).astype(np.int32),
                        max_new=8)
                for i in range(6)]
    engine = TokenEngine(arch, params, batch_size=4, max_len=32)
    results = engine.serve(requests)
    for rid in sorted(results):
        print(f"  req{rid}: {results[rid].tolist()}")
    print(f"  {engine.n_prefill_calls} prefill calls, "
          f"{engine.n_decode_steps} decode rounds, "
          f"compile={engine.compile_stats()}")


def serve_samples() -> None:
    print("== diffusion engine: cifar10-ddpm (reduced config), mixed configs")
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    engine = DiffusionEngine(spec, params, batch_size=4, nfe=10)
    # 6 requests, 4 distinct sampler configs, one engine: previews at 5
    # NFE retire early and their slots are refilled while the q=2
    # corrector renders are still mid-flight.  (4 distinct configs fit the
    # coefficient cache's first config bucket — a 5th would grow the bank
    # and cost a one-time recompile; see docs/serving.md.)
    requests = [
        SampleRequest(rid=0, seed=0),                       # default, 10 NFE
        SampleRequest(rid=1, seed=1, nfe=5),                # fast preview
        SampleRequest(rid=2, seed=2, nfe=5),
        SampleRequest(rid=3, seed=3, nfe=10, q=2, corrector=True),
        SampleRequest(rid=4, seed=4, nfe=5),                # another preview
        SampleRequest(rid=5, seed=5, nfe=8, lam=0.5),       # stochastic
    ]
    results = engine.serve(requests)
    for rid in sorted(results):
        x, r = results[rid], requests[rid]
        cfg = engine.config_of(r)
        print(f"  sample{rid}: nfe={cfg.nfe} q={cfg.q} "
              f"corrector={cfg.corrector} lam={cfg.lam} shape={x.shape} "
              f"mean={x.mean():+.3f} std={x.std():.3f}")
    print(f"  {engine.n_steps} gDDIM rounds, "
          f"{len(engine.cache)} cached sampler configs, "
          f"compile={engine.compile_stats()}")


def serve_families() -> None:
    print("== diffusion engine: VPSDE + CLD + BDM multi-family traffic")
    specs, params = {}, {}
    for i, (fam, name) in enumerate((("vpsde", "cifar10-ddpm"),
                                     ("cld", "cifar10-cld"),
                                     ("bdm", "cifar10-bdm"))):
        specs[fam] = get_diffusion(name, reduced=True)
        params[fam] = specs[fam].init(jax.random.PRNGKey(i))
    engine = DiffusionEngine(specs, params, batch_size=4, nfe=10)
    requests = [
        SampleRequest(rid=0, seed=0),                       # default: vpsde
        SampleRequest(rid=1, seed=1, family="cld", nfe=8),
        SampleRequest(rid=2, seed=2, family="bdm", nfe=6),
        SampleRequest(rid=3, seed=3, family="cld", nfe=8, corrector=True),
        SampleRequest(rid=4, seed=4, family="vpsde", nfe=5),
    ]
    results = engine.serve(requests)
    for rid in sorted(results):
        cfg = engine.config_of(requests[rid])
        x = results[rid]
        print(f"  sample{rid}: family={cfg.family} nfe={cfg.nfe} "
              f"corrector={cfg.corrector} shape={x.shape} "
              f"mean={x.mean():+.3f} std={x.std():.3f}")
    print(f"  {engine.n_rounds} rounds / {engine.n_steps} step dispatches, "
          f"families {engine.families}, "
          f"compile={engine.compile_stats()}")


def serve_routed() -> None:
    print("== router front-tier: 2 engine replicas, wire-form requests")
    spec = get_diffusion("cifar10-ddpm", reduced=True)
    params = spec.init(jax.random.PRNGKey(0))
    # the wire form is the router's ingress: an exact JSON round-trip
    req = ServeRequest(rid=0, workload="diffusion", seed=0, nfe=5)
    assert ServeRequest.from_wire(req.to_wire()) == req
    trace = TraceTraffic(
        [Arrival(float(i), ServeRequest(rid=i, seed=i, nfe=5))
         for i in range(6)])
    engines = [DiffusionEngine(spec, params, batch_size=2, nfe=5)
               for _ in range(2)]
    router = Router([ReplicaSpec(index=i, batch=2) for i in range(2)])
    results, plan = router.serve(trace, engines)
    for a in plan.assignments:
        print(f"  t={a['t']:.1f} req{a['rid']} -> replica {a['replica']}")
    print(f"  {len(results)} served, counters={plan.counters}")


def main():
    for arch in ("rwkv6-7b", "gemma3-1b"):
        serve_tokens(arch)
    serve_samples()
    serve_families()
    serve_routed()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Batched serving walkthrough: continuous batching over the rwkv6 arch
(O(1)/token state) and the gemma3 arch (sliding-window KV cache).

    PYTHONPATH=src python examples/serve_batched.py
"""
import sys
sys.path.insert(0, "src")

from repro.launch import serve


def main():
    for arch in ("rwkv6-7b", "gemma3-1b"):
        print(f"== serving {arch} (reduced config)")
        serve.main(["--arch", arch, "--reduced", "--batch", "4",
                    "--requests", "6", "--prompt-len", "8", "--max-new", "8",
                    "--max-len", "32"])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
